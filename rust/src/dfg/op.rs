//! The operator set.
//!
//! These are Veen's classic static-dataflow operators as the paper lists
//! them (§3.2): `copy`, deterministic merge, non-deterministic merge,
//! `branch`, the relational *deciders*, and the primitive ALU operators.
//! We add two substrate operators the paper's benchmarks imply but do not
//! name — a constant source and a k-bounded FIFO (for stream recirculation
//! in bubble sort) — and document them as extensions in DESIGN.md.



/// The machine word travelling on every data bus: the paper uses 16-bit
/// buses, so all arithmetic is two's-complement 16-bit with wrap-around.
pub type Word = i16;

/// The deepest FIFO a physical fabric slot is provisioned for — and
/// therefore the deepest FIFO any hosted graph may instantiate (the
/// bubble-sort recirculation buffer uses exactly this depth).
pub const MAX_FIFO_DEPTH: u16 = 1024;

/// Operator opcode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    // ---- structural operators --------------------------------------
    /// Duplicate one token to two consumers (1 in, 2 out).
    Copy,
    /// Non-deterministic two-way merge: first token to arrive on either
    /// input is forwarded (2 in, 1 out).
    NdMerge,
    /// Deterministic (controlled) merge: a boolean control token selects
    /// which data input is consumed and forwarded (ctl + 2 data in, 1 out).
    DMerge,
    /// Controlled branch: a boolean control token routes the data token to
    /// the true or the false output (ctl + 1 data in, 2 out).
    Branch,

    // ---- primitive ALU operators (2 in, 1 out) ---------------------
    Add,
    Sub,
    Mul,
    Div,
    And,
    Or,
    Xor,
    Shl,
    Shr,

    // ---- unary (1 in, 1 out) ---------------------------------------
    Not,

    // ---- relational deciders (2 in, 1 boolean out) ------------------
    /// `a > b` — the paper's `gtdecider` / `IFgt`.
    IfGt,
    IfGe,
    IfLt,
    IfLe,
    IfEq,
    /// `a != b` — the paper's `IFdf` ("different").
    IfDf,

    // ---- substrate extensions (documented in DESIGN.md §2) ----------
    /// Emits one constant token at reset, then never again. Used for the
    /// initial tokens the paper wires through `dadoX` init ports.
    Const(Word),
    /// k-bounded FIFO queue (1 in, 1 out). Breaks the single-token rule
    /// *internally* (it is a chain of k arcs in the paper's model); used
    /// for stream recirculation (bubble-sort passes).
    Fifo(u16),
}

/// Coarse operator classes — used by the resource estimator, the VHDL
/// backend (one entity template per class), the vectorized fabric kernel
/// (fire-rule selection), and the physical fabric topology (per-class
/// operator slot pools in [`crate::fabric`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpClass {
    Copy,
    NdMerge,
    DMerge,
    Branch,
    Alu2,
    Alu1,
    Decider,
    Const,
    Fifo,
}

impl OpClass {
    /// Every class, in declaration order (fabric slot-table order).
    pub const ALL: [OpClass; 9] = [
        OpClass::Copy,
        OpClass::NdMerge,
        OpClass::DMerge,
        OpClass::Branch,
        OpClass::Alu2,
        OpClass::Alu1,
        OpClass::Decider,
        OpClass::Const,
        OpClass::Fifo,
    ];

    /// Display name (fabric utilization tables).
    pub fn name(self) -> &'static str {
        match self {
            OpClass::Copy => "copy",
            OpClass::NdMerge => "ndmerge",
            OpClass::DMerge => "dmerge",
            OpClass::Branch => "branch",
            OpClass::Alu2 => "alu2",
            OpClass::Alu1 => "alu1",
            OpClass::Decider => "decider",
            OpClass::Const => "const",
            OpClass::Fifo => "fifo",
        }
    }

    /// The widest (most resource-hungry) member opcode — what a physical
    /// fabric slot of this class must be provisioned for.
    pub fn widest_member(self) -> Op {
        match self {
            OpClass::Copy => Op::Copy,
            OpClass::NdMerge => Op::NdMerge,
            OpClass::DMerge => Op::DMerge,
            OpClass::Branch => Op::Branch,
            OpClass::Alu2 => Op::Mul,
            OpClass::Alu1 => Op::Not,
            OpClass::Decider => Op::IfGt,
            OpClass::Const => Op::Const(0),
            OpClass::Fifo => Op::Fifo(MAX_FIFO_DEPTH),
        }
    }
}

impl Op {
    /// Number of input arcs this operator requires.
    pub fn n_in(self) -> usize {
        match self {
            Op::Const(_) => 0,
            Op::Copy | Op::Not | Op::Fifo(_) => 1,
            Op::DMerge => 3,
            _ => 2,
        }
    }

    /// Number of output arcs this operator drives.
    pub fn n_out(self) -> usize {
        match self {
            Op::Copy | Op::Branch => 2,
            _ => 1,
        }
    }

    pub fn class(self) -> OpClass {
        match self {
            Op::Copy => OpClass::Copy,
            Op::NdMerge => OpClass::NdMerge,
            Op::DMerge => OpClass::DMerge,
            Op::Branch => OpClass::Branch,
            Op::Not => OpClass::Alu1,
            Op::Add
            | Op::Sub
            | Op::Mul
            | Op::Div
            | Op::And
            | Op::Or
            | Op::Xor
            | Op::Shl
            | Op::Shr => OpClass::Alu2,
            Op::IfGt | Op::IfGe | Op::IfLt | Op::IfLe | Op::IfEq | Op::IfDf => OpClass::Decider,
            Op::Const(_) => OpClass::Const,
            Op::Fifo(_) => OpClass::Fifo,
        }
    }

    /// The assembler mnemonic (Listing 1 of the paper).
    pub fn mnemonic(self) -> &'static str {
        match self {
            Op::Copy => "copy",
            Op::NdMerge => "ndmerge",
            Op::DMerge => "dmerge",
            Op::Branch => "branch",
            Op::Add => "add",
            Op::Sub => "sub",
            Op::Mul => "mul",
            Op::Div => "div",
            Op::And => "and",
            Op::Or => "or",
            Op::Xor => "xor",
            Op::Shl => "shl",
            Op::Shr => "shr",
            Op::Not => "not",
            Op::IfGt => "gtdecider",
            Op::IfGe => "gedecider",
            Op::IfLt => "ltdecider",
            Op::IfLe => "ledecider",
            Op::IfEq => "eqdecider",
            Op::IfDf => "dfdecider",
            Op::Const(_) => "const",
            Op::Fifo(_) => "fifo",
        }
    }

    /// Parse an assembler mnemonic (the inverse of [`Op::mnemonic`] for all
    /// parameter-free operators; `const`/`fifo` carry their parameter as a
    /// trailing `#imm` argument handled by the parser).
    pub fn from_mnemonic(s: &str) -> Option<Op> {
        Some(match s {
            "copy" => Op::Copy,
            "ndmerge" => Op::NdMerge,
            "dmerge" => Op::DMerge,
            "branch" => Op::Branch,
            "add" => Op::Add,
            "sub" => Op::Sub,
            "mul" => Op::Mul,
            "div" => Op::Div,
            "and" => Op::And,
            "or" => Op::Or,
            "xor" => Op::Xor,
            "shl" => Op::Shl,
            "shr" => Op::Shr,
            "not" => Op::Not,
            "gtdecider" | "ifgt" => Op::IfGt,
            "gedecider" | "ifge" => Op::IfGe,
            "ltdecider" | "iflt" => Op::IfLt,
            "ledecider" | "ifle" => Op::IfLe,
            "eqdecider" | "ifeq" => Op::IfEq,
            "dfdecider" | "ifdf" => Op::IfDf,
            _ => return None,
        })
    }

    /// Evaluate a 2-input ALU / decider opcode on 16-bit words with the
    /// paper's wrap-around semantics. Division by zero yields 0 (the
    /// hardware's divider is documented to saturate low). Shift counts are
    /// masked to 4 bits (a 16-bit barrel shifter).
    pub fn eval2(self, a: Word, b: Word) -> Word {
        match self {
            Op::Add => a.wrapping_add(b),
            Op::Sub => a.wrapping_sub(b),
            Op::Mul => a.wrapping_mul(b),
            Op::Div => {
                if b == 0 {
                    0
                } else {
                    a.wrapping_div(b)
                }
            }
            Op::And => a & b,
            Op::Or => a | b,
            Op::Xor => a ^ b,
            Op::Shl => a.wrapping_shl((b & 0xf) as u32),
            Op::Shr => a.wrapping_shr((b & 0xf) as u32),
            Op::IfGt => (a > b) as Word,
            Op::IfGe => (a >= b) as Word,
            Op::IfLt => (a < b) as Word,
            Op::IfLe => (a <= b) as Word,
            Op::IfEq => (a == b) as Word,
            Op::IfDf => (a != b) as Word,
            _ => panic!("eval2 on non-binary operator {self:?}"),
        }
    }

    /// Evaluate a unary opcode.
    pub fn eval1(self, a: Word) -> Word {
        match self {
            Op::Not => !a,
            _ => panic!("eval1 on non-unary operator {self:?}"),
        }
    }

    /// A stable small integer id for the vectorized fabric kernel; must
    /// match `OPCODES` in `python/compile/kernels/fabric.py`.
    pub fn fabric_opcode(self) -> i32 {
        match self {
            Op::Add => 0,
            Op::Sub => 1,
            Op::Mul => 2,
            Op::Div => 3,
            Op::And => 4,
            Op::Or => 5,
            Op::Xor => 6,
            Op::Shl => 7,
            Op::Shr => 8,
            Op::IfGt => 9,
            Op::IfGe => 10,
            Op::IfLt => 11,
            Op::IfLe => 12,
            Op::IfEq => 13,
            Op::IfDf => 14,
            Op::Not => 15,
            // Structural ops pass their (selected) input through the ALU
            // unchanged so one kernel covers the whole operator array.
            Op::Copy | Op::NdMerge | Op::DMerge | Op::Branch | Op::Fifo(_) => 16,
            Op::Const(_) => 17,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_matches_paper() {
        // §3.2.1: primitive/relational/ndmerge are 2-in 1-out, dmerge is
        // 3-in 1-out, branch is 2-in 2-out, copy is 1-in 2-out.
        assert_eq!((Op::Add.n_in(), Op::Add.n_out()), (2, 1));
        assert_eq!((Op::IfGt.n_in(), Op::IfGt.n_out()), (2, 1));
        assert_eq!((Op::NdMerge.n_in(), Op::NdMerge.n_out()), (2, 1));
        assert_eq!((Op::DMerge.n_in(), Op::DMerge.n_out()), (3, 1));
        assert_eq!((Op::Branch.n_in(), Op::Branch.n_out()), (2, 2));
        assert_eq!((Op::Copy.n_in(), Op::Copy.n_out()), (1, 2));
    }

    #[test]
    fn eval2_wraps_16bit() {
        assert_eq!(Op::Add.eval2(i16::MAX, 1), i16::MIN);
        assert_eq!(Op::Mul.eval2(256, 256), 0);
        assert_eq!(Op::Sub.eval2(i16::MIN, 1), i16::MAX);
    }

    #[test]
    fn div_by_zero_is_zero() {
        assert_eq!(Op::Div.eval2(123, 0), 0);
        assert_eq!(Op::Div.eval2(-7, 2), -3);
    }

    #[test]
    fn deciders_are_boolean() {
        for op in [Op::IfGt, Op::IfGe, Op::IfLt, Op::IfLe, Op::IfEq, Op::IfDf] {
            for (a, b) in [(3, 5), (5, 3), (4, 4), (-1, 1)] {
                let v = op.eval2(a, b);
                assert!(v == 0 || v == 1, "{op:?}({a},{b}) = {v}");
            }
        }
    }

    #[test]
    fn mnemonic_roundtrip() {
        for op in [
            Op::Copy,
            Op::NdMerge,
            Op::DMerge,
            Op::Branch,
            Op::Add,
            Op::Sub,
            Op::Mul,
            Op::Div,
            Op::And,
            Op::Or,
            Op::Xor,
            Op::Shl,
            Op::Shr,
            Op::Not,
            Op::IfGt,
            Op::IfGe,
            Op::IfLt,
            Op::IfLe,
            Op::IfEq,
            Op::IfDf,
        ] {
            assert_eq!(Op::from_mnemonic(op.mnemonic()), Some(op));
        }
    }

    #[test]
    fn shifts_mask_to_4_bits() {
        assert_eq!(Op::Shl.eval2(1, 16), 1); // 16 & 0xf == 0
        assert_eq!(Op::Shl.eval2(1, 4), 16);
        assert_eq!(Op::Shr.eval2(-16, 2), -4); // arithmetic shift
    }
}
