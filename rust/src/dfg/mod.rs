//! Dataflow-graph IR.
//!
//! A graph is a set of operator [`Node`]s connected by [`Arc`]s. An arc is
//! the paper's 16-bit parallel data bus plus its `str`/`ack` control pair
//! (Fig. 2); under the **static** dataflow rule it can hold at most one
//! token at any time. Arcs with no producer are *input ports* (data is
//! injected from the environment) and arcs with no consumer are *output
//! ports* (tokens are collected by the environment), matching the paper's
//! `dadoa..dadoj` / `fibo` / `pf` signals.

mod builder;
mod graph;
mod op;
pub mod schema;
mod validate;

pub use builder::GraphBuilder;
pub use graph::{is_anon_label, Arc, ArcId, Graph, Node, NodeId, PortDir};
pub use op::{Op, OpClass, Word, MAX_FIFO_DEPTH};
pub use schema::build_loop;
pub use validate::{validate, ValidateError};
