//! The canonical loop schema.
//!
//! Every benchmark in the paper is (at heart) a counted or conditional
//! loop, and its dataflow graph is the classic Dennis *while-schema* the
//! Fibonacci graph (Fig. 7) instantiates: per loop variable a merge node
//! re-admits either the initial value or the back-edge value, copies feed
//! the loop condition, a decider produces the control token, a copy tree
//! fans the control out, and one branch per variable routes it back into
//! the body (TRUE) or out to the exit (FALSE).
//!
//! [`build_loop`] generates that schema. It is used both by the hand-built
//! benchmark graphs in [`crate::bench_defs`] and by the mini-C frontend's
//! loop lowering, and it nests: an inner loop's init arcs may come from an
//! outer loop's body, in which case the inner loop re-initializes on every
//! outer iteration (see the bubble-sort graph).

use super::builder::GraphBuilder;
use super::graph::ArcId;
use super::op::Op;

/// Build a `while cond(vars) { vars = body(vars) }` schema.
///
/// * `inits` — one arc per loop variable carrying its initial token
///   (a `Const`, an input port, or an arc produced by an enclosing loop).
/// * `cond_uses` — indices of the variables the condition reads; those are
///   copied so both the condition and the body see them.
/// * `cond` — receives one arc per `cond_uses` entry (same order) and must
///   return a boolean (0/1) arc, typically from a decider.
/// * `body` — receives the gated variable arcs (TRUE side of the branches)
///   and must return exactly one *next-value* arc per variable. Returning
///   a gated arc unchanged makes that variable loop-invariant.
///
/// Returns the exit arcs (FALSE side of the branches), one per variable,
/// in variable order. Unused exits dangle as anonymous output ports; name
/// the interesting ones with [`GraphBuilder::rename_arc`].
pub fn build_loop(
    b: &mut GraphBuilder,
    inits: &[ArcId],
    cond_uses: &[usize],
    cond: impl FnOnce(&mut GraphBuilder, &[ArcId]) -> ArcId,
    body: impl FnOnce(&mut GraphBuilder, &[ArcId]) -> Vec<ArcId>,
) -> Vec<ArcId> {
    let n = inits.len();
    assert!(n > 0, "a loop needs at least one variable");
    assert!(cond_uses.iter().all(|&i| i < n), "cond_uses out of range");

    // Merged values: pre-created wires, driven by the merge nodes at the
    // end (the builder allows using an arc before its driver exists).
    let merged: Vec<ArcId> = (0..n).map(|_| b.wire()).collect();

    // Condition taps: vars the condition reads are copied; the branch-data
    // side uses the other copy. Everything else goes straight to a branch.
    let mut branch_data: Vec<ArcId> = Vec::with_capacity(n);
    let mut cond_args: Vec<ArcId> = Vec::with_capacity(cond_uses.len());
    for (i, &m) in merged.iter().enumerate() {
        if cond_uses.contains(&i) {
            let (c_arc, d_arc) = b.copy(m);
            cond_args.push(c_arc);
            branch_data.push(d_arc);
        } else {
            branch_data.push(m);
        }
    }
    // `cond_args` was filled in ascending variable order (one tap per
    // distinct variable); hand them to `cond` in `cond_uses` order. A
    // condition reading the same variable twice must copy it itself.
    let mut sorted: Vec<usize> = cond_uses.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(
        sorted.len(),
        cond_uses.len(),
        "cond_uses must be distinct; copy inside `cond` to reuse a variable"
    );
    let ordered: Vec<ArcId> = cond_uses
        .iter()
        .map(|&u| cond_args[sorted.iter().position(|&v| v == u).unwrap()])
        .collect();

    let ctl = cond(b, &ordered);

    // Fan the control token out to one branch per variable.
    let ctl_taps = b.copy_n(ctl, n);

    // Branches: TRUE → gated (into body), FALSE → exit.
    let mut gated = Vec::with_capacity(n);
    let mut exits = Vec::with_capacity(n);
    for i in 0..n {
        let nid = b.node(Op::Branch, &[ctl_taps[i], branch_data[i]], &[]);
        gated.push(b.out_arc(nid, 0));
        exits.push(b.out_arc(nid, 1));
    }

    // Body computes next values.
    let next = body(b, &gated);
    assert_eq!(
        next.len(),
        n,
        "body must return one next-value arc per loop variable"
    );

    // Merges close the cycle: NdMerge(init, back) → merged wire. The init
    // token always arrives before the first back-edge token, so the
    // non-determinism is benign (§3.2 item 4).
    for i in 0..n {
        b.node(Op::NdMerge, &[inits[i], next[i]], &[merged[i]]);
    }

    exits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::Op;
    use crate::sim::{run_dynamic, run_fsm, run_token, SimConfig};

    /// sum = Σ_{i<n} i, the smallest interesting counted loop.
    fn sum_graph() -> crate::dfg::Graph {
        let mut b = GraphBuilder::new("sum");
        let n = b.input_port("n");
        let i0 = b.constant(0);
        let one0 = b.constant(1);
        let acc0 = b.constant(0);
        let exits = build_loop(
            &mut b,
            &[i0, n, one0, acc0],
            &[0, 1],
            |b, c| b.op2(Op::IfLt, c[0], c[1]),
            |b, g| {
                // i' = i + 1 (uses a copy of `one`); acc' = acc + i.
                let (one_use, one_back) = b.copy(g[2]);
                let (i_use, i_acc) = b.copy(g[0]);
                let i_next = b.op2(Op::Add, i_use, one_use);
                let acc_next = b.op2(Op::Add, g[3], i_acc);
                vec![i_next, g[1], one_back, acc_next]
            },
        );
        b.rename_arc(exits[3], "sum");
        b.finish().unwrap()
    }

    #[test]
    fn counted_loop_sums() {
        let g = sum_graph();
        for n in [0i16, 1, 5, 10, 100] {
            let cfg = SimConfig::new().inject("n", vec![n]);
            let out = run_token(&g, &cfg);
            let expect: i16 = (0..n).sum();
            assert_eq!(out.last("sum"), Some(expect), "n={n}");
            assert!(out.quiescent, "n={n} must quiesce");
        }
    }

    #[test]
    fn all_three_engines_agree_on_loop() {
        let g = sum_graph();
        let cfg = SimConfig::new().inject("n", vec![7]);
        let tok = run_token(&g, &cfg);
        let fsm = run_fsm(&g, &cfg);
        let dy = run_dynamic(&g, &cfg, 4);
        assert_eq!(tok.last("sum"), Some(21));
        assert_eq!(fsm.outputs.get("sum"), tok.outputs.get("sum"));
        assert_eq!(dy.outputs.get("sum"), tok.outputs.get("sum"));
    }

    #[test]
    fn nested_loops_reinitialize() {
        // total = Σ_{k<m} Σ_{i<n} 1  == m*n
        let mut b = GraphBuilder::new("nest");
        let m = b.input_port("m");
        let n = b.input_port("n");
        let k0 = b.constant(0);
        let one0 = b.constant(1);
        let zero0 = b.constant(0);
        let tot0 = b.constant(0);
        let exits = build_loop(
            &mut b,
            &[k0, m, one0, zero0, tot0, n],
            &[0, 1],
            |b, c| b.op2(Op::IfLt, c[0], c[1]),
            |b, g| {
                // inner: for i in 0..n { t += 1 }
                let (one_k, one_in) = b.copy(g[2]);
                let (zero_in, zero_back) = b.copy(g[3]);
                let (n_in_0, _n_unused) = (g[5], ());
                let inner_exits = build_loop(
                    b,
                    &[zero_in, n_in_0, one_in, g[4]],
                    &[0, 1],
                    |b, c| b.op2(Op::IfLt, c[0], c[1]),
                    |b, g| {
                        let (one_use, one_back) = b.copy(g[2]);
                        let i_next = b.op2(Op::Add, g[0], one_use);
                        let (one_use2, one_back2) = b.copy(one_back);
                        let t_next = b.op2(Op::Add, g[3], one_use2);
                        vec![i_next, g[1], one_back2, t_next]
                    },
                );
                let k_next = b.op2(Op::Add, g[0], one_k);
                // inner exits: [i_f, n_f, one_f, t_f]
                vec![
                    k_next,
                    g[1],
                    inner_exits[2],
                    zero_back,
                    inner_exits[3],
                    inner_exits[1],
                ]
            },
        );
        b.rename_arc(exits[4], "total");
        let g = b.finish().unwrap();
        for (m_v, n_v) in [(0, 5), (3, 0), (2, 3), (4, 4)] {
            let cfg = SimConfig::new()
                .inject("m", vec![m_v])
                .inject("n", vec![n_v])
                .max_cycles(200_000);
            let out = run_token(&g, &cfg);
            assert_eq!(out.last("total"), Some(m_v * n_v), "m={m_v} n={n_v}");
        }
    }

    #[test]
    fn single_token_invariant_holds_during_loop() {
        let g = sum_graph();
        let cfg = SimConfig::new().inject("n", vec![12]);
        let mut sim = crate::sim::TokenSim::new(&g, &cfg);
        for _ in 0..5000 {
            sim.step();
            // occupancy() counts arcs holding a token; by construction an
            // arc can never hold two (Option<Word>), but the invariant we
            // check is global sanity: never more tokens than arcs.
            assert!(sim.occupancy() <= g.n_arcs());
        }
    }
}
