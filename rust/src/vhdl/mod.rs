//! VHDL backend — the artifact format the paper's assembler produced.
//!
//! One entity per operator *class* (the paper's three architectures,
//! §3.2.1: 2-in/1-out, dmerge's 3-in/1-out, branch's 2-in/2-out, plus
//! copy's 1-in/2-out), each implementing the Fig. 6 ASM chart: `S0`
//! reset, `S1` receive/latch + ack, `S2` execute, `S3` strobe out. The
//! top-level architecture instantiates one component per node and one
//! `(data, str, ack)` signal triple per arc — exactly the netlist the
//! paper's assembler emits from Listing-1 text.
//!
//! We cannot run ISE on the output, so tests validate structure: entity
//! set, instantiation count, signal count, port-map arity, determinism.

mod emit;

pub use emit::{generate, VhdlDesign};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{build, BenchId};
    use crate::dfg::{GraphBuilder, Op};

    fn small() -> crate::dfg::Graph {
        let mut b = GraphBuilder::new("small");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let (x, y) = b.copy(b.graph().arcs[a.0 as usize].id);
        let s = b.op2(Op::Add, x, c);
        let z = b.output_port("z");
        b.node(Op::Xor, &[s, y], &[z]);
        b.finish().unwrap()
    }

    #[test]
    fn generates_one_instance_per_node() {
        let g = small();
        let d = generate(&g);
        let instances = d.top.matches(": entity work.").count();
        assert_eq!(instances, g.n_nodes());
    }

    #[test]
    fn generates_signal_triples_per_internal_arc() {
        let g = small();
        let d = generate(&g);
        for arc in &g.arcs {
            if arc.src.is_some() && arc.dst.is_some() {
                assert!(
                    d.top.contains(&format!("signal {}_data", arc.name)),
                    "missing data signal for {}",
                    arc.name
                );
                assert!(d.top.contains(&format!("signal {}_str", arc.name)));
                assert!(d.top.contains(&format!("signal {}_ack", arc.name)));
            }
        }
    }

    #[test]
    fn ports_become_toplevel_ports() {
        let g = small();
        let d = generate(&g);
        assert!(d.top.contains("a_data : in  std_logic_vector(15 downto 0)"));
        assert!(d.top.contains("z_data : out std_logic_vector(15 downto 0)"));
        assert!(d.top.contains("z_str : out std_logic"));
        assert!(d.top.contains("a_ack : out std_logic"));
    }

    #[test]
    fn entity_set_covers_used_classes_only() {
        let g = small();
        let d = generate(&g);
        let names: Vec<&str> = d.entities.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"dfop_alu2")); // add/xor
        assert!(names.contains(&"dfop_copy"));
        assert!(!names.contains(&"dfop_branch")); // unused class not emitted
    }

    #[test]
    fn alu_entity_has_paper_fsm() {
        let g = small();
        let d = generate(&g);
        let alu = &d
            .entities
            .iter()
            .find(|(n, _)| n == "dfop_alu2")
            .unwrap()
            .1;
        // The four ASM-chart states and the Fig. 5 registers.
        for s in ["S0", "S1", "S2", "S3", "dadoa", "dadob", "dadoz", "bita", "bitb", "bitz"] {
            assert!(alu.contains(s), "entity lacks {s}");
        }
    }

    #[test]
    fn all_benchmarks_generate() {
        for b in BenchId::ALL {
            let g = build(b);
            let d = generate(&g);
            assert!(d.top.contains(&format!("entity {} is", g.name)));
            assert!(!d.entities.is_empty());
            // Deterministic output.
            let d2 = generate(&g);
            assert_eq!(d.render(), d2.render());
        }
    }

    #[test]
    fn const_and_fifo_parameterized_via_generics() {
        let mut b = GraphBuilder::new("t");
        let k = b.constant(42);
        let q = b.wire();
        b.node(Op::Fifo(16), &[k], &[q]);
        let z = b.output_port("z");
        b.node(Op::Not, &[q], &[z]);
        let g = b.finish().unwrap();
        let d = generate(&g);
        assert!(d.top.contains("VALUE => 42"));
        assert!(d.top.contains("DEPTH => 16"));
    }
}
