//! VHDL text generation.

use crate::dfg::{Graph, Op, OpClass};
use std::collections::BTreeSet;
use std::fmt::Write;

/// A generated design: shared operator entities plus the top netlist.
#[derive(Debug, Clone)]
pub struct VhdlDesign {
    /// `(entity_name, vhdl_text)` for every operator class the graph uses.
    pub entities: Vec<(String, String)>,
    /// Top-level entity + architecture instantiating the graph.
    pub top: String,
}

impl VhdlDesign {
    /// The whole design as one compilation unit (entities first).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (_, e) in &self.entities {
            out.push_str(e);
            out.push('\n');
        }
        out.push_str(&self.top);
        out
    }
}

fn class_entity_name(c: OpClass) -> &'static str {
    match c {
        OpClass::Copy => "dfop_copy",
        OpClass::NdMerge => "dfop_ndmerge",
        OpClass::DMerge => "dfop_dmerge",
        OpClass::Branch => "dfop_branch",
        OpClass::Alu2 => "dfop_alu2",
        OpClass::Alu1 => "dfop_alu1",
        OpClass::Decider => "dfop_decider",
        OpClass::Const => "dfop_const",
        OpClass::Fifo => "dfop_fifo",
    }
}

/// Opcode generic value for shared ALU / decider entities.
fn opcode_generic(op: Op) -> Option<&'static str> {
    Some(match op {
        Op::Add => "OP_ADD",
        Op::Sub => "OP_SUB",
        Op::Mul => "OP_MUL",
        Op::Div => "OP_DIV",
        Op::And => "OP_AND",
        Op::Or => "OP_OR",
        Op::Xor => "OP_XOR",
        Op::Shl => "OP_SHL",
        Op::Shr => "OP_SHR",
        Op::IfGt => "OP_GT",
        Op::IfGe => "OP_GE",
        Op::IfLt => "OP_LT",
        Op::IfLe => "OP_LE",
        Op::IfEq => "OP_EQ",
        Op::IfDf => "OP_DF",
        _ => return None,
    })
}

const HEADER: &str = "\
library ieee;
use ieee.std_logic_1164.all;
use ieee.numeric_std.all;
";

/// The shared package of opcode constants.
fn package() -> String {
    let mut s = String::from(HEADER);
    s.push_str(
        "
package dfop_pkg is
  constant OP_ADD : integer := 0;  constant OP_SUB : integer := 1;
  constant OP_MUL : integer := 2;  constant OP_DIV : integer := 3;
  constant OP_AND : integer := 4;  constant OP_OR  : integer := 5;
  constant OP_XOR : integer := 6;  constant OP_SHL : integer := 7;
  constant OP_SHR : integer := 8;  constant OP_GT  : integer := 9;
  constant OP_GE  : integer := 10; constant OP_LT  : integer := 11;
  constant OP_LE  : integer := 12; constant OP_EQ  : integer := 13;
  constant OP_DF  : integer := 14;
end package;
",
    );
    s
}

/// Emit the two-input operator entity (primitive ALU / decider / shared
/// datapath of Fig. 5 driven by the ASM chart of Fig. 6).
fn entity_alu2(name: &str, boolean_out: bool) -> String {
    let result = if boolean_out {
        "dadoz <= (0 => result_bit, others => '0');"
    } else {
        "dadoz <= result_word;"
    };
    format!(
        "{HEADER}use work.dfop_pkg.all;

entity {name} is
  generic (OPCODE : integer := OP_ADD);
  port (
    clk, rst : in std_logic;
    a    : in  std_logic_vector(15 downto 0);
    stra : in  std_logic;
    acka : out std_logic;
    b    : in  std_logic_vector(15 downto 0);
    strb : in  std_logic;
    ackb : out std_logic;
    z    : out std_logic_vector(15 downto 0);
    strz : out std_logic;
    ackz : in  std_logic);
end entity;

architecture rtl of {name} is
  type state_t is (S0, S1, S2, S3);
  signal state : state_t;
  signal dadoa, dadob, dadoz : std_logic_vector(15 downto 0);
  signal bita, bitb, bitz : std_logic;
  signal result_word : std_logic_vector(15 downto 0);
  signal result_bit : std_logic;
begin
  -- Fig. 6 ASM chart: S0 reset, S1 receive, S2 execute, S3 send.
  process (clk)
  begin
    if rising_edge(clk) then
      if rst = '1' then
        state <= S0;
        bita <= '0'; bitb <= '0'; bitz <= '0';
        acka <= '0'; ackb <= '0'; strz <= '0';
      else
        case state is
          when S0 =>
            state <= S1;
          when S1 =>
            if stra = '1' and bita = '0' then
              dadoa <= a; bita <= '1'; acka <= '1';
            else
              acka <= '0';
            end if;
            if strb = '1' and bitb = '0' then
              dadob <= b; bitb <= '1'; ackb <= '1';
            else
              ackb <= '0';
            end if;
            if bita = '1' and bitb = '1' then
              state <= S2;
            end if;
          when S2 =>
            {result}
            bitz <= '1';
            state <= S3;
          when S3 =>
            strz <= '1';
            if ackz = '1' then
              strz <= '0'; bitz <= '0';
              bita <= '0'; bitb <= '0';
              state <= S1;
            end if;
        end case;
      end if;
    end if;
  end process;

  z <= dadoz;

  -- Combinational function unit, selected by the OPCODE generic.
  alu : process (dadoa, dadob)
    variable va, vb : signed(15 downto 0);
  begin
    va := signed(dadoa); vb := signed(dadob);
    result_word <= (others => '0'); result_bit <= '0';
    case OPCODE is
      when OP_ADD => result_word <= std_logic_vector(va + vb);
      when OP_SUB => result_word <= std_logic_vector(va - vb);
      when OP_MUL => result_word <= std_logic_vector(resize(va * vb, 16));
      when OP_DIV =>
        if vb /= 0 then
          result_word <= std_logic_vector(va / vb);
        end if;
      when OP_AND => result_word <= dadoa and dadob;
      when OP_OR  => result_word <= dadoa or dadob;
      when OP_XOR => result_word <= dadoa xor dadob;
      when OP_SHL =>
        result_word <= std_logic_vector(shift_left(va, to_integer(vb(3 downto 0))));
      when OP_SHR =>
        result_word <= std_logic_vector(shift_right(va, to_integer(vb(3 downto 0))));
      when OP_GT => if va >  vb then result_bit <= '1'; end if;
      when OP_GE => if va >= vb then result_bit <= '1'; end if;
      when OP_LT => if va <  vb then result_bit <= '1'; end if;
      when OP_LE => if va <= vb then result_bit <= '1'; end if;
      when OP_EQ => if va =  vb then result_bit <= '1'; end if;
      when OP_DF => if va /= vb then result_bit <= '1'; end if;
      when others => null;
    end case;
  end process;

  {assign}
end architecture;
",
        assign = result
    )
}

/// Structural entities whose bodies differ from the ALU template only in
/// the receive/execute rules; emitted as compact hand templates.
fn entity_fixed(name: &str) -> String {
    let body: &str = match name {
        "dfop_copy" => "\
entity dfop_copy is
  port (
    clk, rst : in std_logic;
    a : in std_logic_vector(15 downto 0); stra : in std_logic; acka : out std_logic;
    z0 : out std_logic_vector(15 downto 0); strz0 : out std_logic; ackz0 : in std_logic;
    z1 : out std_logic_vector(15 downto 0); strz1 : out std_logic; ackz1 : in std_logic);
end entity;
architecture rtl of dfop_copy is
  type state_t is (S0, S1, S2, S3);
  signal state : state_t;
  signal dadoa, dadoz : std_logic_vector(15 downto 0);
  signal bita, bitz : std_logic;
  signal sent0, sent1 : std_logic;
begin
  process (clk) begin
    if rising_edge(clk) then
      if rst = '1' then
        state <= S0; bita <= '0'; bitz <= '0';
        acka <= '0'; strz0 <= '0'; strz1 <= '0'; sent0 <= '0'; sent1 <= '0';
      else
        case state is
          when S0 => state <= S1;
          when S1 =>
            if stra = '1' and bita = '0' then
              dadoa <= a; bita <= '1'; acka <= '1';
            else acka <= '0'; end if;
            if bita = '1' then state <= S2; end if;
          when S2 =>
            dadoz <= dadoa; bitz <= '1'; state <= S3;
          when S3 =>
            if sent0 = '0' then strz0 <= '1'; end if;
            if sent1 = '0' then strz1 <= '1'; end if;
            if ackz0 = '1' then strz0 <= '0'; sent0 <= '1'; end if;
            if ackz1 = '1' then strz1 <= '0'; sent1 <= '1'; end if;
            if (sent0 = '1' or ackz0 = '1') and (sent1 = '1' or ackz1 = '1') then
              bitz <= '0'; bita <= '0'; sent0 <= '0'; sent1 <= '0';
              strz0 <= '0'; strz1 <= '0';
              state <= S1;
            end if;
        end case;
      end if;
    end if;
  end process;
  z0 <= dadoz; z1 <= dadoz;
end architecture;
",
        "dfop_alu1" => "\
entity dfop_alu1 is
  port (
    clk, rst : in std_logic;
    a : in std_logic_vector(15 downto 0); stra : in std_logic; acka : out std_logic;
    z : out std_logic_vector(15 downto 0); strz : out std_logic; ackz : in std_logic);
end entity;
architecture rtl of dfop_alu1 is
  type state_t is (S0, S1, S2, S3);
  signal state : state_t;
  signal dadoa, dadoz : std_logic_vector(15 downto 0);
  signal bita, bitz : std_logic;
begin
  process (clk) begin
    if rising_edge(clk) then
      if rst = '1' then
        state <= S0; bita <= '0'; bitz <= '0'; acka <= '0'; strz <= '0';
      else
        case state is
          when S0 => state <= S1;
          when S1 =>
            if stra = '1' and bita = '0' then
              dadoa <= a; bita <= '1'; acka <= '1';
            else acka <= '0'; end if;
            if bita = '1' then state <= S2; end if;
          when S2 => dadoz <= not dadoa; bitz <= '1'; state <= S3;
          when S3 =>
            strz <= '1';
            if ackz = '1' then
              strz <= '0'; bitz <= '0'; bita <= '0'; state <= S1;
            end if;
        end case;
      end if;
    end if;
  end process;
  z <= dadoz;
end architecture;
",
        "dfop_ndmerge" => "\
entity dfop_ndmerge is
  port (
    clk, rst : in std_logic;
    a : in std_logic_vector(15 downto 0); stra : in std_logic; acka : out std_logic;
    b : in std_logic_vector(15 downto 0); strb : in std_logic; ackb : out std_logic;
    z : out std_logic_vector(15 downto 0); strz : out std_logic; ackz : in std_logic);
end entity;
architecture rtl of dfop_ndmerge is
  type state_t is (S0, S1, S2, S3);
  signal state : state_t;
  signal dadoa, dadob, dadoz : std_logic_vector(15 downto 0);
  signal bita, bitb, bitz : std_logic;
  signal take_a : std_logic;
begin
  process (clk) begin
    if rising_edge(clk) then
      if rst = '1' then
        state <= S0; bita <= '0'; bitb <= '0'; bitz <= '0';
        acka <= '0'; ackb <= '0'; strz <= '0';
      else
        case state is
          when S0 => state <= S1;
          when S1 =>
            if stra = '1' and bita = '0' then
              dadoa <= a; bita <= '1'; acka <= '1';
            else acka <= '0'; end if;
            if strb = '1' and bitb = '0' then
              dadob <= b; bitb <= '1'; ackb <= '1';
            else ackb <= '0'; end if;
            -- fixed-priority arbiter: port a wins ties
            if bita = '1' then take_a <= '1'; state <= S2;
            elsif bitb = '1' then take_a <= '0'; state <= S2;
            end if;
          when S2 =>
            if take_a = '1' then dadoz <= dadoa; bita <= '0';
            else dadoz <= dadob; bitb <= '0'; end if;
            bitz <= '1'; state <= S3;
          when S3 =>
            strz <= '1';
            if ackz = '1' then
              strz <= '0'; bitz <= '0'; state <= S1;
            end if;
        end case;
      end if;
    end if;
  end process;
  z <= dadoz;
end architecture;
",
        "dfop_dmerge" => "\
entity dfop_dmerge is
  port (
    clk, rst : in std_logic;
    c : in std_logic_vector(15 downto 0); strc : in std_logic; ackc : out std_logic;
    a : in std_logic_vector(15 downto 0); stra : in std_logic; acka : out std_logic;
    b : in std_logic_vector(15 downto 0); strb : in std_logic; ackb : out std_logic;
    z : out std_logic_vector(15 downto 0); strz : out std_logic; ackz : in std_logic);
end entity;
architecture rtl of dfop_dmerge is
  type state_t is (S0, S1, S2, S3);
  signal state : state_t;
  signal dadoc, dadoa, dadob, dadoz : std_logic_vector(15 downto 0);
  signal bitc, bita, bitb, bitz : std_logic;
begin
  process (clk) begin
    if rising_edge(clk) then
      if rst = '1' then
        state <= S0; bitc <= '0'; bita <= '0'; bitb <= '0'; bitz <= '0';
        ackc <= '0'; acka <= '0'; ackb <= '0'; strz <= '0';
      else
        case state is
          when S0 => state <= S1;
          when S1 =>
            if strc = '1' and bitc = '0' then
              dadoc <= c; bitc <= '1'; ackc <= '1';
            else ackc <= '0'; end if;
            if stra = '1' and bita = '0' then
              dadoa <= a; bita <= '1'; acka <= '1';
            else acka <= '0'; end if;
            if strb = '1' and bitb = '0' then
              dadob <= b; bitb <= '1'; ackb <= '1';
            else ackb <= '0'; end if;
            -- TRUE selects a, FALSE selects b; the other register parks.
            if bitc = '1' and dadoc /= x\"0000\" and bita = '1' then state <= S2; end if;
            if bitc = '1' and dadoc = x\"0000\" and bitb = '1' then state <= S2; end if;
          when S2 =>
            if dadoc /= x\"0000\" then dadoz <= dadoa; bita <= '0';
            else dadoz <= dadob; bitb <= '0'; end if;
            bitc <= '0'; bitz <= '1'; state <= S3;
          when S3 =>
            strz <= '1';
            if ackz = '1' then
              strz <= '0'; bitz <= '0'; state <= S1;
            end if;
        end case;
      end if;
    end if;
  end process;
  z <= dadoz;
end architecture;
",
        "dfop_branch" => "\
entity dfop_branch is
  port (
    clk, rst : in std_logic;
    c : in std_logic_vector(15 downto 0); strc : in std_logic; ackc : out std_logic;
    a : in std_logic_vector(15 downto 0); stra : in std_logic; acka : out std_logic;
    t : out std_logic_vector(15 downto 0); strt : out std_logic; ackt : in std_logic;
    f : out std_logic_vector(15 downto 0); strf : out std_logic; ackf : in std_logic);
end entity;
architecture rtl of dfop_branch is
  type state_t is (S0, S1, S2, S3);
  signal state : state_t;
  signal dadoc, dadoa, dadoz : std_logic_vector(15 downto 0);
  signal bitc, bita, bitz : std_logic;
  signal to_t : std_logic;
begin
  process (clk) begin
    if rising_edge(clk) then
      if rst = '1' then
        state <= S0; bitc <= '0'; bita <= '0'; bitz <= '0';
        ackc <= '0'; acka <= '0'; strt <= '0'; strf <= '0';
      else
        case state is
          when S0 => state <= S1;
          when S1 =>
            if strc = '1' and bitc = '0' then
              dadoc <= c; bitc <= '1'; ackc <= '1';
            else ackc <= '0'; end if;
            if stra = '1' and bita = '0' then
              dadoa <= a; bita <= '1'; acka <= '1';
            else acka <= '0'; end if;
            if bitc = '1' and bita = '1' then state <= S2; end if;
          when S2 =>
            dadoz <= dadoa;
            if dadoc /= x\"0000\" then to_t <= '1'; else to_t <= '0'; end if;
            bitz <= '1'; state <= S3;
          when S3 =>
            if to_t = '1' then
              strt <= '1';
              if ackt = '1' then
                strt <= '0'; bitz <= '0'; bitc <= '0'; bita <= '0'; state <= S1;
              end if;
            else
              strf <= '1';
              if ackf = '1' then
                strf <= '0'; bitz <= '0'; bitc <= '0'; bita <= '0'; state <= S1;
              end if;
            end if;
        end case;
      end if;
    end if;
  end process;
  t <= dadoz; f <= dadoz;
end architecture;
",
        "dfop_const" => "\
entity dfop_const is
  generic (VALUE : integer := 0);
  port (
    clk, rst : in std_logic;
    z : out std_logic_vector(15 downto 0); strz : out std_logic; ackz : in std_logic);
end entity;
architecture rtl of dfop_const is
  signal spent : std_logic;
begin
  process (clk) begin
    if rising_edge(clk) then
      if rst = '1' then
        spent <= '0'; strz <= '0';
      else
        if spent = '0' then
          strz <= '1';
          if ackz = '1' then strz <= '0'; spent <= '1'; end if;
        end if;
      end if;
    end if;
  end process;
  z <= std_logic_vector(to_signed(VALUE, 16));
end architecture;
",
        "dfop_fifo" => "\
entity dfop_fifo is
  generic (DEPTH : integer := 16);
  port (
    clk, rst : in std_logic;
    a : in std_logic_vector(15 downto 0); stra : in std_logic; acka : out std_logic;
    z : out std_logic_vector(15 downto 0); strz : out std_logic; ackz : in std_logic);
end entity;
architecture rtl of dfop_fifo is
  type mem_t is array (0 to DEPTH - 1) of std_logic_vector(15 downto 0);
  signal mem : mem_t;
  signal rd, wr : integer range 0 to DEPTH - 1;
  signal count : integer range 0 to DEPTH;
begin
  process (clk) begin
    if rising_edge(clk) then
      if rst = '1' then
        rd <= 0; wr <= 0; count <= 0; acka <= '0'; strz <= '0';
      else
        acka <= '0';
        if stra = '1' and count < DEPTH then
          mem(wr) <= a; wr <= (wr + 1) mod DEPTH;
          count <= count + 1; acka <= '1';
        end if;
        if count > 0 then
          strz <= '1';
          if ackz = '1' then
            rd <= (rd + 1) mod DEPTH; count <= count - 1; strz <= '0';
          end if;
        else
          strz <= '0';
        end if;
      end if;
    end if;
  end process;
  z <= mem(rd);
end architecture;
",
        other => panic!("no fixed template for {other}"),
    };
    format!("{HEADER}use work.dfop_pkg.all;\n\n{body}")
}

fn entity_text(c: OpClass) -> String {
    match c {
        OpClass::Alu2 => entity_alu2("dfop_alu2", false),
        OpClass::Decider => entity_alu2("dfop_decider", true),
        other => entity_fixed(class_entity_name(other)),
    }
}

/// Port names of each entity, in node-port order (ins then outs).
fn port_names(c: OpClass) -> (&'static [&'static str], &'static [&'static str]) {
    match c {
        OpClass::Copy => (&["a"], &["z0", "z1"]),
        OpClass::NdMerge => (&["a", "b"], &["z"]),
        OpClass::DMerge => (&["c", "a", "b"], &["z"]),
        OpClass::Branch => (&["c", "a"], &["t", "f"]),
        OpClass::Alu2 | OpClass::Decider => (&["a", "b"], &["z"]),
        OpClass::Alu1 => (&["a"], &["z"]),
        OpClass::Const => (&[], &["z"]),
        OpClass::Fifo => (&["a"], &["z"]),
    }
}

/// Strobe/ack suffixes mirror the data port names.
fn hs(port: &str) -> (String, String) {
    (format!("str{port}"), format!("ack{port}"))
}

/// Generate the complete design for a graph.
pub fn generate(g: &Graph) -> VhdlDesign {
    // Entities: package + one entity per used class, in stable order.
    let used: BTreeSet<&'static str> = g
        .nodes
        .iter()
        .map(|n| class_entity_name(n.op.class()))
        .collect();
    let mut entities = vec![("dfop_pkg".to_string(), package())];
    for n in &g.nodes {
        let c = n.op.class();
        let name = class_entity_name(c);
        if used.contains(name) && !entities.iter().any(|(en, _)| en == name) {
            entities.push((name.to_string(), entity_text(c)));
        }
    }

    // Top level.
    let mut top = String::from(HEADER);
    let _ = writeln!(top, "use work.dfop_pkg.all;\n");
    let _ = writeln!(top, "entity {} is", g.name);
    let _ = writeln!(top, "  port (");
    let _ = writeln!(top, "    clk, rst : in std_logic;");
    let mut port_lines = Vec::new();
    for a in &g.arcs {
        if a.is_input_port() {
            port_lines.push(format!(
                "    {0}_data : in  std_logic_vector(15 downto 0);\n    \
                 {0}_str : in std_logic;\n    {0}_ack : out std_logic",
                a.name
            ));
        } else if a.is_output_port() {
            port_lines.push(format!(
                "    {0}_data : out std_logic_vector(15 downto 0);\n    \
                 {0}_str : out std_logic;\n    {0}_ack : in std_logic",
                a.name
            ));
        }
    }
    top.push_str(&port_lines.join(";\n"));
    let _ = writeln!(top, ");");
    let _ = writeln!(top, "end entity;\n");
    let _ = writeln!(top, "architecture structural of {} is", g.name);
    for a in &g.arcs {
        if a.src.is_some() && a.dst.is_some() {
            let _ = writeln!(
                top,
                "  signal {0}_data : std_logic_vector(15 downto 0);\n  \
                 signal {0}_str : std_logic;\n  signal {0}_ack : std_logic;",
                a.name
            );
        }
    }
    let _ = writeln!(top, "begin");
    for n in &g.nodes {
        let c = n.op.class();
        let ent = class_entity_name(c);
        let (in_ports, out_ports) = port_names(c);
        let mut maps = vec![
            "clk => clk".to_string(),
            "rst => rst".to_string(),
        ];
        match n.op {
            Op::Const(v) => maps.insert(0, format!("VALUE => {v}")),
            Op::Fifo(d) => maps.insert(0, format!("DEPTH => {d}")),
            _ => {
                if let Some(oc) = opcode_generic(n.op) {
                    maps.insert(0, format!("OPCODE => {oc}"));
                }
            }
        }
        let generic_split = matches!(n.op, Op::Const(_) | Op::Fifo(_))
            || opcode_generic(n.op).is_some();
        for (p, &arc) in n.ins.iter().enumerate() {
            let pname = in_ports[p];
            let (s, k) = hs(pname);
            let a = g.arc(arc);
            maps.push(format!("{pname} => {}_data", a.name));
            maps.push(format!("{s} => {}_str", a.name));
            maps.push(format!("{k} => {}_ack", a.name));
        }
        for (p, &arc) in n.outs.iter().enumerate() {
            let pname = out_ports[p];
            let (s, k) = hs(pname);
            let a = g.arc(arc);
            maps.push(format!("{pname} => {}_data", a.name));
            maps.push(format!("{s} => {}_str", a.name));
            maps.push(format!("{k} => {}_ack", a.name));
        }
        let (generics, ports): (Vec<_>, Vec<_>) = if generic_split {
            (vec![maps.remove(0)], maps)
        } else {
            (vec![], maps)
        };
        let _ = write!(top, "  n{} : entity work.{ent}", n.id.0);
        if !generics.is_empty() {
            let _ = write!(top, " generic map ({})", generics.join(", "));
        }
        let _ = writeln!(top, "\n    port map ({});", ports.join(", "));
    }
    let _ = writeln!(top, "end architecture;");

    VhdlDesign { entities, top }
}
