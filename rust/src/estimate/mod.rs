//! Structural resource + timing estimation.
//!
//! The paper reports post-synthesis FF / LUT / Slice counts and maximum
//! frequency from Xilinx ISE 13.1 on a Virtex-7 (7v285tffg1157-3). We have
//! no synthesis toolchain, so this module computes the same quantities
//! *structurally* from the RTL inventory the VHDL backend emits — the
//! registers of Fig. 5, the ASM-chart FSM of Fig. 6, and the per-class ALU
//! logic — using per-primitive costs for a Virtex-class 6-input-LUT
//! fabric.
//!
//! Two models are reported:
//!
//! * [`estimate_raw`] — every register the RTL declares (three 16-bit data
//!   registers per binary operator, presence bits, FSM). This is what the
//!   paper's Fig. 5 datapath literally instantiates.
//! * [`estimate`] — the *post-synthesis* model: cross-operator register
//!   retiming merges each consumer input register into the producer output
//!   register (one register per arc), and arcs that only ever carry
//!   booleans (decider outputs feeding `branch`/`dmerge` control ports)
//!   are trimmed to 1 bit. This mirrors what ISE's retiming/trimming does
//!   and is the model Table 1 is reproduced with.
//!
//! The paper's own Table 1 FF counts are smaller than its Fig. 5 datapath
//! can possibly synthesize to (e.g. Fibonacci: 20 operators × 3 × 16-bit
//! registers ≫ 72 FF), so absolute matching is impossible by
//! construction; EXPERIMENTS.md compares *orderings and ratios*, which is
//! what Fig. 8 argues from. DESIGN.md §2 discusses this discrepancy.

mod fmax;
mod model;

pub use fmax::{critical_path_ns, fmax_mhz, op_delay_ns};
pub use model::{
    estimate, estimate_raw, estimate_shards, estimate_trimmed, op_cost, op_resources, OpCost,
    Resources, WORD_BITS,
};
