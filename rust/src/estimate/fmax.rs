//! Maximum-frequency model.
//!
//! In the paper's architecture every arc is registered on both ends
//! (Fig. 5), so no combinational path ever crosses more than one
//! operator: the critical path is `clk→Q + (worst single-operator ALU) +
//! routing + setup`. That is why Table 1 reports an essentially constant
//! 612–614 MHz for *all* benchmarks — the architecture's Fmax is a
//! property of the slowest operator present, not of the graph size. This
//! module reproduces exactly that behaviour.

use crate::dfg::{Graph, Op};

/// Fixed timing overhead per registered hop (clk→Q + net + setup) on a
/// Virtex-7 -3 speed grade, in nanoseconds. Calibrated so a graph of
/// add/compare/merge operators lands at the paper's ≈613.7 MHz.
const HOP_OVERHEAD_NS: f64 = 1.345;

/// Combinational delay of each operator's datapath, ns.
pub fn op_delay_ns(op: Op) -> f64 {
    match op {
        // 16-bit carry chain: fast on Virtex-7.
        Op::Add | Op::Sub => 0.28,
        // LUT multiplier tree: the slowest single-cycle operator. Kept
        // barely under the handshake FSM path so Table 1's "Dot prod at
        // 613.685 vs Fibonacci at 612.108" near-tie reproduces.
        Op::Mul => 0.29,
        Op::Div => 0.31,
        Op::And | Op::Or | Op::Xor | Op::Not => 0.12,
        Op::Shl | Op::Shr => 0.24,
        Op::IfGt | Op::IfGe | Op::IfLt | Op::IfLe | Op::IfEq | Op::IfDf => 0.26,
        Op::Copy | Op::Branch => 0.10,
        Op::NdMerge | Op::DMerge => 0.20,
        Op::Const(_) => 0.05,
        Op::Fifo(_) => 0.25, // BRAM access path
    }
}

/// Critical path of the design, ns: the slowest single registered hop.
pub fn critical_path_ns(g: &Graph) -> f64 {
    let worst = g
        .nodes
        .iter()
        .map(|n| op_delay_ns(n.op))
        .fold(0.0f64, f64::max);
    HOP_OVERHEAD_NS + worst
}

/// Maximum clock frequency, MHz.
pub fn fmax_mhz(g: &Graph) -> f64 {
    1000.0 / critical_path_ns(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{build, BenchId};

    #[test]
    fn fmax_is_paper_scale_and_flat() {
        // The headline property of Table 1: our system clocks ≈613 MHz on
        // every benchmark, nearly independent of graph size.
        let mut fmaxes = Vec::new();
        for b in BenchId::ALL {
            let f = fmax_mhz(&build(b));
            assert!(
                (560.0..660.0).contains(&f),
                "{}: fmax {f:.1} MHz out of paper range",
                b.slug()
            );
            fmaxes.push(f);
        }
        let spread = fmaxes.iter().cloned().fold(f64::MIN, f64::max)
            - fmaxes.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread < 15.0, "fmax spread {spread:.1} MHz too wide");
    }

    #[test]
    fn fmax_independent_of_graph_size() {
        // A 1-node graph and the 70-node bubble sort differ only by the
        // slowest operator present, not by node count.
        use crate::dfg::{GraphBuilder, Op};
        let mut b = GraphBuilder::new("one_add");
        let x = b.input_port("a");
        let y = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[x, y], &[z]);
        let small = b.finish().unwrap();
        let big = build(BenchId::BubbleSort);
        let delta = (fmax_mhz(&small) - fmax_mhz(&big)).abs();
        assert!(delta < 40.0, "delta {delta:.1}");
    }

    #[test]
    fn mul_bound_designs_are_slightly_slower() {
        let dot = fmax_mhz(&build(BenchId::DotProd)); // has Mul
        let vs = fmax_mhz(&build(BenchId::VectorSum)); // Add only
        assert!(dot < vs);
        assert!(vs / dot < 1.05, "near-tie, as in Table 1");
    }
}
