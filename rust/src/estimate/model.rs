//! FF/LUT/slice models.

use crate::dfg::{Graph, Op, OpClass};

/// Data-bus width (the paper's 16-bit parallel buses, §3.1).
pub const WORD_BITS: u32 = 16;

/// Estimated resources for one design.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    pub ff: u32,
    pub lut: u32,
    pub slices: u32,
    /// Block-RAM bits (FIFO substrate only; the paper's operator set has
    /// no memory, so this is zero for all Table-1 graphs except the
    /// bubble-sort recirculation buffer).
    pub bram_bits: u32,
    pub fmax_mhz: f64,
}

impl Resources {
    pub fn add(&mut self, o: &Resources) {
        self.ff += o.ff;
        self.lut += o.lut;
        self.slices += o.slices;
        self.bram_bits += o.bram_bits;
    }
}

/// Per-operator primitive costs (Virtex-class fabric, 6-input LUTs).
#[derive(Debug, Clone, Copy)]
pub struct OpCost {
    /// ALU/datapath LUTs for the operator's combinational function.
    pub alu_lut: u32,
    /// Extra control LUTs beyond the standard FSM decode.
    pub ctl_lut: u32,
}

/// Combinational cost of each operator class/opcode.
pub fn op_cost(op: Op) -> OpCost {
    let w = WORD_BITS;
    match op {
        // 16-bit ripple/carry-chain adder or subtractor: 1 LUT/bit.
        Op::Add | Op::Sub => OpCost { alu_lut: w, ctl_lut: 0 },
        // LUT-mapped 16×16 multiplier (no DSP on the paper's flow is
        // stated; a Booth-ish LUT array is ≈ w²/2 + w).
        Op::Mul => OpCost { alu_lut: w * w / 2 + w, ctl_lut: 4 },
        // Iterative restoring divider: subtractor + shifter + control.
        Op::Div => OpCost { alu_lut: w * 3 + 24, ctl_lut: 8 },
        // Bitwise: 1 LUT per bit (two operands fit one 6-LUT).
        Op::And | Op::Or | Op::Xor => OpCost { alu_lut: w, ctl_lut: 0 },
        Op::Not => OpCost { alu_lut: w, ctl_lut: 0 },
        // 16-bit barrel shifter: log2(w) mux stages ≈ w·4/2.
        Op::Shl | Op::Shr => OpCost { alu_lut: w * 2, ctl_lut: 0 },
        // Comparator: carry-chain compare, ~1 LUT per 2 bits + sign.
        Op::IfGt | Op::IfGe | Op::IfLt | Op::IfLe | Op::IfEq | Op::IfDf => OpCost {
            alu_lut: w / 2 + 2,
            ctl_lut: 0,
        },
        // Structural operators: muxes / demux enables.
        Op::Copy => OpCost { alu_lut: 0, ctl_lut: 2 },
        Op::NdMerge => OpCost { alu_lut: w, ctl_lut: 3 }, // 2:1 mux + arbiter
        Op::DMerge => OpCost { alu_lut: w, ctl_lut: 2 },  // 2:1 mux
        Op::Branch => OpCost { alu_lut: 0, ctl_lut: 4 },  // demux enables
        Op::Const(_) => OpCost { alu_lut: 0, ctl_lut: 1 },
        Op::Fifo(_) => OpCost { alu_lut: 8, ctl_lut: 6 }, // pointers + full/empty
    }
}

/// FSM + handshake cost shared by every operator (Fig. 6): 2 state FF,
/// ~3 LUTs of next-state decode, plus 1 FF + 1 LUT per port of strobe /
/// acknowledge logic (Fig. 3).
fn fsm_cost(op: Op) -> (u32, u32) {
    let ports = (op.n_in() + op.n_out()) as u32;
    let ff = 2 + ports; // state + bita/bitb/bitz presence bits
    let lut = 3 + ports;
    (ff, lut)
}

/// Is this arc's payload a 1-bit boolean? True when it is driven by a
/// decider and/or consumed by a control port (branch/dmerge port 0) —
/// synthesis trims such buses to one bit.
fn arc_is_control(g: &Graph, arc: crate::dfg::ArcId) -> bool {
    let a = g.arc(arc);
    let driven_by_decider = a
        .src
        .map(|(n, _)| g.node(n).op.class() == OpClass::Decider)
        .unwrap_or(false);
    let consumed_as_ctl = a
        .dst
        .map(|(n, p)| {
            matches!(g.node(n).op, Op::Branch | Op::DMerge) && p == 0
        })
        .unwrap_or(false);
    driven_by_decider || consumed_as_ctl
}

/// Slice packing: Virtex-7 slices hold 4 LUTs + 8 FF; real packers
/// achieve ~60-70% LUT packing on control-heavy designs, and the
/// paper's netlists are extremely routing-dominated (every operator has
/// its own handshake nets), which is why Table 1's slice counts exceed
/// its LUT counts. We model that with a routing-expansion term
/// proportional to arc count.
fn pack_slices(ff: u32, lut: u32, n_arcs: u32) -> u32 {
    let by_lut = (lut as f64 / 2.6).ceil() as u32; // poor packing
    let by_ff = (ff as f64 / 8.0).ceil() as u32;
    by_lut.max(by_ff) + n_arcs // routing-only slices, one per channel
}

/// Resources of a single operator instance (FSM + handshake + ALU) —
/// the unit a fabric topology provisions per operator slot. `fmax_mhz`
/// is zero: one operator has no netlist-level critical path of its own.
pub fn op_resources(op: Op) -> Resources {
    let (fsm_ff, fsm_lut) = fsm_cost(op);
    let c = op_cost(op);
    let mut r = Resources {
        ff: fsm_ff,
        lut: fsm_lut + c.alu_lut + c.ctl_lut,
        ..Resources::default()
    };
    if let Op::Fifo(depth) = op {
        r.bram_bits += depth as u32 * WORD_BITS;
        r.ff += 2 * 11;
    }
    r
}

/// Per-shard resource estimates plus the pool total for a partitioned
/// graph. The total's `fmax_mhz` is the *slowest* shard's — in a
/// multi-fabric deployment every instance runs the same clock domain
/// discipline, so the critical shard bounds the system.
pub fn estimate_shards<'a>(
    shards: impl IntoIterator<Item = &'a Graph>,
) -> (Vec<Resources>, Resources) {
    let mut per = Vec::new();
    let mut total = Resources::default();
    for g in shards {
        let r = estimate(g);
        total.add(&r);
        per.push(r);
    }
    total.fmax_mhz = per
        .iter()
        .map(|r| r.fmax_mhz)
        .fold(f64::INFINITY, f64::min);
    if per.is_empty() {
        total.fmax_mhz = 0.0;
    }
    (per, total)
}

/// Post-synthesis model: one data register per *arc* (producer output
/// register; consumer input registers retimed away), boolean arcs trimmed
/// to 1 bit, FSM + handshake per node, ALU logic per opcode.
pub fn estimate(g: &Graph) -> Resources {
    let mut r = Resources::default();
    for n in &g.nodes {
        r.add(&op_resources(n.op));
    }
    for a in &g.arcs {
        // One register per arc, at the payload's trimmed width.
        let width = if arc_is_control(g, a.id) { 1 } else { WORD_BITS };
        r.ff += width;
    }
    r.slices = pack_slices(r.ff, r.lut, g.n_arcs() as u32);
    r.fmax_mhz = super::fmax_mhz(g);
    r
}

/// Control-only ("as the paper synthesized") model.
///
/// Table 1's FF counts for the paper's own system are far below what its
/// Fig. 5 datapath can synthesize to (Fibonacci: 72 FF for ~20 operators,
/// i.e. ~3.5 FF per operator — just the FSM state and presence bits).
/// The only consistent explanation is that ISE trimmed the entire 16-bit
/// datapath (top-level data buses left unconnected), keeping the control
/// plane: FSMs, presence bits, handshake nets — which also explains why
/// the LUT and slice counts stay high while FF collapses. This model
/// reproduces that measurement so the paper's FF/LUT *orderings* can be
/// checked; [`estimate`] remains the honest full-datapath model.
pub fn estimate_trimmed(g: &Graph) -> Resources {
    let mut r = Resources::default();
    for n in &g.nodes {
        let (fsm_ff, fsm_lut) = fsm_cost(n.op);
        let c = op_cost(n.op);
        r.ff += fsm_ff;
        r.lut += fsm_lut + c.alu_lut + c.ctl_lut;
    }
    // One presence bit per arc survives (the token is control state).
    r.ff += g.n_arcs() as u32;
    r.slices = pack_slices(r.ff, r.lut, g.n_arcs() as u32);
    r.fmax_mhz = super::fmax_mhz(g);
    r
}

/// Raw RTL model: every register Fig. 5 declares (input + output data
/// registers at full width, presence bits, FSM), no trimming.
pub fn estimate_raw(g: &Graph) -> Resources {
    let mut r = Resources::default();
    for n in &g.nodes {
        r.add(&op_resources(n.op));
        // Input AND output data registers at full width (no retiming).
        r.ff += (n.op.n_in() + n.op.n_out()) as u32 * WORD_BITS;
    }
    r.slices = pack_slices(r.ff, r.lut, g.n_arcs() as u32);
    r.fmax_mhz = super::fmax_mhz(g);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{build, BenchId};
    use crate::dfg::{GraphBuilder, Op};

    fn adder_graph() -> Graph {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, c], &[z]);
        b.finish().unwrap()
    }

    #[test]
    fn single_adder_costs_are_sane() {
        let r = estimate(&adder_graph());
        // 3 arcs × 16 FF + FSM(2 + 3 ports) = 48 + 5 = 53 FF.
        assert_eq!(r.ff, 53);
        // FSM decode (3+3) + 16 ALU LUTs.
        assert_eq!(r.lut, 22);
        assert!(r.slices > 0);
        assert!(r.fmax_mhz > 100.0);
    }

    #[test]
    fn raw_model_is_strictly_larger() {
        for b in BenchId::ALL {
            let g = build(b);
            let post = estimate(&g);
            let raw = estimate_raw(&g);
            assert!(raw.ff > post.ff, "{}: raw {} ≤ post {}", b.slug(), raw.ff, post.ff);
            assert_eq!(raw.lut, post.lut); // trimming only affects FF here
        }
    }

    #[test]
    fn multiplier_dominates_dot_prod_luts() {
        // The paper's Dot prod row is its FF/LUT outlier; our model must
        // reproduce that the multiplier makes dot_prod the most
        // LUT-expensive of the loop benchmarks (bubble sort aside).
        let dot = estimate(&build(BenchId::DotProd));
        let fib = estimate(&build(BenchId::Fibonacci));
        let max = estimate(&build(BenchId::Max));
        assert!(dot.lut > fib.lut);
        assert!(dot.lut > max.lut);
    }

    #[test]
    fn bubble_sort_is_biggest() {
        let bubble = estimate(&build(BenchId::BubbleSort));
        for b in [BenchId::Fibonacci, BenchId::Max, BenchId::VectorSum] {
            let r = estimate(&build(b));
            assert!(bubble.ff > r.ff, "bubble vs {}", b.slug());
            assert!(bubble.lut > r.lut, "bubble vs {}", b.slug());
        }
        assert!(bubble.bram_bits > 0);
    }

    #[test]
    fn control_arcs_are_trimmed() {
        // decider → branch ctl: that arc costs 1 FF, not 16.
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let c0 = b.input_port("c0");
        let d = b.input_port("d");
        let cond = b.op2(Op::IfGt, a, c0);
        let t = b.output_port("t");
        let f = b.output_port("f");
        b.node(Op::Branch, &[cond, d], &[t, f]);
        let g = b.finish().unwrap();
        let r = estimate(&g);
        // arcs: a,c0,d,t,f = 16×5; cond = 1.
        let arc_ff: u32 = 16 * 5 + 1;
        let fsm_ff = (2 + 3) + (2 + 4); // decider ports=3, branch ports=4
        assert_eq!(r.ff, arc_ff + fsm_ff);
    }
}
