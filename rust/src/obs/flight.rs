//! Flight recorder: the last N events per tenant, kept by the chaos serve
//! path so a failed gate can dump an actionable timeline instead of a bare
//! counter mismatch.
//!
//! Unlike [`crate::obs::TraceBuf`] this is a serial, single-owner
//! structure (`&mut` recording, no locks) because the chaos path is
//! contractually serial; it trades concurrency for a guaranteed-contiguous
//! per-tenant tail.

use crate::obs::trace::TraceEvent;
use std::collections::VecDeque;

/// Bounded per-tenant tail of [`TraceEvent`]s.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FlightRecorder {
    cap: usize,
    lanes: Vec<VecDeque<TraceEvent>>,
}

impl FlightRecorder {
    /// Default per-tenant tail length.
    pub const DEFAULT_TAIL: usize = 64;

    pub fn new(n_tenants: usize, cap: usize) -> Self {
        FlightRecorder {
            cap: cap.max(1),
            lanes: vec![VecDeque::new(); n_tenants],
        }
    }

    /// Record one event into its tenant's lane, evicting the oldest when
    /// the tail is full. Events with [`TraceEvent::NO_TENANT`] (or any
    /// out-of-range tenant) are dropped — the recorder only answers
    /// per-tenant questions.
    pub fn record(&mut self, ev: TraceEvent) {
        let Some(lane) = self.lanes.get_mut(ev.tenant as usize) else {
            return;
        };
        if lane.len() >= self.cap {
            lane.pop_front();
        }
        lane.push_back(ev);
    }

    /// The recorded tail for `tenant`, oldest first. Empty for unknown
    /// tenants.
    pub fn timeline(&self, tenant: u32) -> Vec<TraceEvent> {
        self.lanes
            .get(tenant as usize)
            .map_or_else(Vec::new, |l| l.iter().copied().collect())
    }

    /// Total events currently held across all tenants.
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::SpanKind;

    fn ev(tenant: u32, seq: u64) -> TraceEvent {
        TraceEvent {
            kind: SpanKind::Retry,
            tenant,
            seq,
            tick: seq,
            cycles: 0,
            engine: "chaos",
            detail: 1,
        }
    }

    #[test]
    fn keeps_only_the_tail() {
        let mut fr = FlightRecorder::new(2, 3);
        for seq in 0..5 {
            fr.record(ev(0, seq));
        }
        fr.record(ev(1, 99));
        fr.record(ev(TraceEvent::NO_TENANT, 0)); // silently ignored
        let tl = fr.timeline(0);
        assert_eq!(tl.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![2, 3, 4]);
        assert_eq!(fr.timeline(1).len(), 1);
        assert!(fr.timeline(7).is_empty());
        assert_eq!(fr.len(), 4);
    }
}
