//! Trace/profile exporters: Chrome `trace_event` JSON and the compact
//! self-describing `OBS_9.json` artifact (schema `dataflow-accel-obs/v1`).
//!
//! Everything serialized from the record path is virtual (ticks, cycles,
//! counters). Wall clock may be attached here — and only here — as an
//! export-time sidecar field (`wall_clock_ns`), which the determinism
//! checks deliberately ignore: they compare [`events_json`] output, which
//! contains no wall-clock data by construction.

use crate::obs::prof::EngineProfile;
use crate::obs::registry::FamilySnapshot;
use crate::obs::trace::TraceEvent;
use std::fmt::Write as _;

/// Everything one `trace` invocation wants to persist.
pub struct ObsArtifact<'a> {
    /// Where the trace came from ("bench:saxpy", "serve", "serve-chaos").
    pub source: &'a str,
    /// Canonical-order event stream (see `TraceBuf::drain_sorted`).
    pub events: &'a [TraceEvent],
    /// Labeled engine profiles ("token", "lanes", ...).
    pub profiles: &'a [(String, EngineProfile)],
    /// Counter-family snapshots from `obs::registry`.
    pub families: &'a [FamilySnapshot],
    /// Events lost to ring-buffer overflow (always present in the JSON).
    pub dropped: u64,
    /// Optional export-time wall-clock sidecar; never part of the
    /// deterministic view.
    pub wall_clock_ns: Option<u64>,
}

/// Serialize just the event stream — the **deterministic view**. The
/// `obs_determinism_*` properties and the CI worker-count comparison both
/// assert byte equality of this string.
pub fn events_json(events: &[TraceEvent]) -> String {
    let mut out = String::from("[\n");
    for (i, e) in events.iter().enumerate() {
        let comma = if i + 1 < events.len() { "," } else { "" };
        writeln!(
            out,
            "    {{\"kind\": \"{}\", \"tenant\": {}, \"seq\": {}, \"tick\": {}, \
             \"cycles\": {}, \"engine\": \"{}\", \"detail\": {}}}{comma}",
            e.kind.name(),
            e.tenant,
            e.seq,
            e.tick,
            e.cycles,
            e.engine,
            e.detail
        )
        .unwrap();
    }
    out.push_str("  ]");
    out
}

fn profile_json(out: &mut String, label: &str, p: &EngineProfile) {
    writeln!(out, "      \"label\": \"{label}\",").unwrap();
    writeln!(out, "      \"engine\": \"{}\",", p.engine).unwrap();
    writeln!(out, "      \"level\": \"{:?}\",", p.level).unwrap();
    writeln!(out, "      \"cycles\": {},", p.cycles).unwrap();
    writeln!(out, "      \"total_firings\": {},", p.total_firings).unwrap();
    let nodes: Vec<String> = p
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, s)| s.firings > 0 || s.stall_total() > 0)
        .map(|(i, s)| {
            format!(
                "{{\"node\": {i}, \"firings\": {}, \"input_starved\": {}, \
                 \"output_blocked\": {}, \"gate_closed\": {}}}",
                s.firings, s.input_starved, s.output_blocked, s.gate_closed
            )
        })
        .collect();
    writeln!(out, "      \"nodes\": [{}],", nodes.join(", ")).unwrap();
    let occ: Vec<String> = p
        .arc_occupancy
        .iter()
        .enumerate()
        .filter(|(_, o)| **o > 0)
        .map(|(i, o)| format!("[{i}, {o}]"))
        .collect();
    writeln!(out, "      \"arc_occupancy\": [{}],", occ.join(", ")).unwrap();
    let ops: Vec<String> = p
        .opcode_density
        .iter()
        .map(|(k, v)| format!("\"{k}\": {v}"))
        .collect();
    writeln!(out, "      \"opcode_density\": {{{}}},", ops.join(", ")).unwrap();
    let cuts: Vec<String> = p.cut_traffic.iter().map(|t| t.to_string()).collect();
    writeln!(out, "      \"cut_traffic\": [{}]", cuts.join(", ")).unwrap();
}

/// Serialize the full artifact (schema `dataflow-accel-obs/v1`).
pub fn obs_json(a: &ObsArtifact) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"dataflow-accel-obs/v1\",\n");
    writeln!(out, "  \"source\": \"{}\",", a.source).unwrap();
    writeln!(out, "  \"dropped\": {},", a.dropped).unwrap();
    match a.wall_clock_ns {
        Some(ns) => writeln!(out, "  \"wall_clock_ns\": {ns},").unwrap(),
        None => out.push_str("  \"wall_clock_ns\": null,\n"),
    }
    writeln!(out, "  \"span_count\": {},", a.events.len()).unwrap();
    writeln!(out, "  \"events\": {},", events_json(a.events)).unwrap();
    out.push_str("  \"profiles\": [\n");
    for (i, (label, p)) in a.profiles.iter().enumerate() {
        let comma = if i + 1 < a.profiles.len() { "," } else { "" };
        out.push_str("    {\n");
        profile_json(&mut out, label, p);
        writeln!(out, "    }}{comma}").unwrap();
    }
    out.push_str("  ],\n");
    out.push_str("  \"counters\": [\n");
    for (i, f) in a.families.iter().enumerate() {
        let comma = if i + 1 < a.families.len() { "," } else { "" };
        let rows: Vec<String> = f.rows().map(|(n, v)| format!("\"{n}\": {v}")).collect();
        writeln!(
            out,
            "    {{\"family\": \"{}\", \"values\": {{{}}}}}{comma}",
            f.family,
            rows.join(", ")
        )
        .unwrap();
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    out
}

/// Serialize events as Chrome `trace_event` JSON (load via
/// `chrome://tracing` or <https://ui.perfetto.dev>). Virtual ticks map to
/// microseconds, cycles to duration; tenants become processes and engines
/// become threads.
pub fn chrome_trace(events: &[TraceEvent]) -> String {
    let mut out = String::from("{\"traceEvents\": [\n");
    for (i, e) in events.iter().enumerate() {
        let comma = if i + 1 < events.len() { "," } else { "" };
        // Complete events need dur >= 1 to be visible; instants stay "i".
        let (ph, dur) = if e.cycles > 0 {
            ("X", e.cycles)
        } else {
            ("i", 0)
        };
        let mut line = format!(
            "  {{\"name\": \"{}\", \"ph\": \"{ph}\", \"ts\": {}, \"pid\": {}, \
             \"tid\": \"{}\"",
            e.kind.name(),
            e.tick,
            e.tenant,
            e.engine
        );
        if ph == "X" {
            write!(line, ", \"dur\": {dur}").unwrap();
        } else {
            line.push_str(", \"s\": \"t\"");
        }
        write!(
            line,
            ", \"args\": {{\"seq\": {}, \"detail\": {}}}}}{comma}",
            e.seq, e.detail
        )
        .unwrap();
        out.push_str(&line);
        out.push('\n');
    }
    out.push_str("]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::prof::ProfileLevel;
    use crate::obs::trace::SpanKind;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent {
                kind: SpanKind::Admit,
                tenant: 0,
                seq: 1,
                tick: 0,
                cycles: 0,
                engine: "sched",
                detail: 0,
            },
            TraceEvent {
                kind: SpanKind::Execute,
                tenant: 0,
                seq: 1,
                tick: 2,
                cycles: 33,
                engine: "lanes",
                detail: 0,
            },
        ]
    }

    #[test]
    fn events_json_is_pure_function_of_events() {
        let evs = sample_events();
        assert_eq!(events_json(&evs), events_json(&evs.clone()));
        assert!(events_json(&evs).contains("\"kind\": \"execute\""));
        assert!(!events_json(&evs).contains("wall"));
    }

    #[test]
    fn obs_json_has_schema_dropped_and_span_count() {
        let evs = sample_events();
        let mut p = EngineProfile::new("lanes", ProfileLevel::Full, 2, 2);
        p.fire_n(1, 3);
        let art = ObsArtifact {
            source: "bench:saxpy",
            events: &evs,
            profiles: &[("lanes".to_string(), p)],
            families: &[],
            dropped: 0,
            wall_clock_ns: None,
        };
        let j = obs_json(&art);
        assert!(j.contains("\"schema\": \"dataflow-accel-obs/v1\""));
        assert!(j.contains("\"dropped\": 0"));
        assert!(j.contains("\"span_count\": 2"));
        assert!(j.contains("\"wall_clock_ns\": null"));
        assert!(j.contains("\"total_firings\": 3"));
    }

    #[test]
    fn chrome_trace_marks_spans_and_instants() {
        let j = chrome_trace(&sample_events());
        assert!(j.contains("\"ph\": \"X\""));
        assert!(j.contains("\"ph\": \"i\""));
        assert!(j.contains("\"dur\": 33"));
        assert!(j.starts_with("{\"traceEvents\""));
    }
}
