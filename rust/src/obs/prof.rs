//! Per-node / per-arc profiling for the execution engines.
//!
//! Every engine (`TokenSim`, `LaneSim`, `StreamSession`) owns an
//! `Option<Box<EngineProfile>>` that is `None` unless profiling was
//! explicitly enabled — the hot path pays exactly one pointer-null branch
//! when off, and zero allocations (pinned by `obs_determinism_off_*` and
//! the `bench --trace-overhead` A/B).
//!
//! Stall attribution taxonomy (DESIGN.md §12): when a node is *attempted*
//! by its engine's scheduler but refuses to fire, the refusal is charged to
//! exactly one of three causes, checked in this order:
//!
//! 1. **input-starved** — some required input arc carries no token;
//! 2. **output-blocked** — inputs ready, but an output arc still holds an
//!    unconsumed token (back-pressure);
//! 3. **gate-closed** — node-specific gating with tokens in place: a
//!    `const` that already emitted its once-per-wave value, a `fifo` at
//!    capacity, or a wave-tag mismatch holding a token for a later wave.

use std::collections::BTreeMap;

/// How much the engines record. `Off` is the default everywhere and is
/// contractually free: no allocation, no counter traffic, digests
/// unchanged.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum ProfileLevel {
    /// No profiling state is allocated at all.
    #[default]
    Off,
    /// Per-node firing + stall counters only.
    Counters,
    /// `Counters` plus per-arc occupancy integrals and opcode densities.
    Full,
}

/// One of the three stall-attribution buckets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StallCause {
    InputStarved,
    OutputBlocked,
    GateClosed,
}

/// Per-node counters: firings plus stall-cycles by cause.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NodeStats {
    pub firings: u64,
    pub input_starved: u64,
    pub output_blocked: u64,
    pub gate_closed: u64,
}

impl NodeStats {
    pub fn stall_total(&self) -> u64 {
        self.input_starved + self.output_blocked + self.gate_closed
    }
}

/// Everything one engine run recorded. Built by
/// `enable_profiling(level)` on the engine, harvested by `take_profile()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineProfile {
    pub level: ProfileLevel,
    /// Engine label ("token", "lanes", "stream", "sharded", "reconfig").
    pub engine: &'static str,
    /// Indexed by node id.
    pub nodes: Vec<NodeStats>,
    /// Rounds each arc held a token, indexed by arc id (`Full` only).
    pub arc_occupancy: Vec<u64>,
    /// Lane tier: mnemonic → lane-firings (mask-popcount sum, `Full` only).
    pub opcode_density: BTreeMap<&'static str, u64>,
    /// Tokens moved per cut arc (sharded/reconfig tiers), by cut index.
    pub cut_traffic: Vec<u64>,
    /// Engine cycles/rounds covered by this profile.
    pub cycles: u64,
    /// Sum of `nodes[i].firings` — must equal the engine's own firing
    /// total; the `trace` CLI refuses to export when they disagree.
    pub total_firings: u64,
}

impl EngineProfile {
    pub fn new(engine: &'static str, level: ProfileLevel, n_nodes: usize, n_arcs: usize) -> Self {
        let full = level >= ProfileLevel::Full;
        EngineProfile {
            level,
            engine,
            nodes: vec![NodeStats::default(); n_nodes],
            arc_occupancy: if full { vec![0; n_arcs] } else { Vec::new() },
            opcode_density: BTreeMap::new(),
            cut_traffic: Vec::new(),
            cycles: 0,
            total_firings: 0,
        }
    }

    /// Record one firing of node `ni`.
    pub fn fire(&mut self, ni: usize) {
        self.fire_n(ni, 1);
    }

    /// Record `n` simultaneous firings of node `ni` (lane masks).
    pub fn fire_n(&mut self, ni: usize, n: u64) {
        self.nodes[ni].firings += n;
        self.total_firings += n;
    }

    /// Record one refused firing attempt of node `ni`.
    pub fn stall(&mut self, ni: usize, cause: StallCause) {
        let s = &mut self.nodes[ni];
        match cause {
            StallCause::InputStarved => s.input_starved += 1,
            StallCause::OutputBlocked => s.output_blocked += 1,
            StallCause::GateClosed => s.gate_closed += 1,
        }
    }

    /// Add `n` rounds of occupancy to arc `arc` (`Full` only — caller
    /// gates, this method just accumulates when the vec exists).
    pub fn occupy(&mut self, arc: usize, n: u64) {
        if let Some(o) = self.arc_occupancy.get_mut(arc) {
            *o += n;
        }
    }

    /// Add `lanes` lane-firings under opcode `mnemonic`.
    pub fn opcode(&mut self, mnemonic: &'static str, lanes: u64) {
        *self.opcode_density.entry(mnemonic).or_insert(0) += lanes;
    }

    /// Add `n` tokens moved over cut `ci` (vec grows on demand).
    pub fn cut(&mut self, ci: usize, n: u64) {
        if self.cut_traffic.len() <= ci {
            self.cut_traffic.resize(ci + 1, 0);
        }
        self.cut_traffic[ci] += n;
    }

    /// Fold another profile into this one (sharded/lane-chunk merges).
    pub fn merge(&mut self, other: &EngineProfile) {
        if self.nodes.len() < other.nodes.len() {
            self.nodes.resize(other.nodes.len(), NodeStats::default());
        }
        for (i, s) in other.nodes.iter().enumerate() {
            let d = &mut self.nodes[i];
            d.firings += s.firings;
            d.input_starved += s.input_starved;
            d.output_blocked += s.output_blocked;
            d.gate_closed += s.gate_closed;
        }
        if self.arc_occupancy.len() < other.arc_occupancy.len() {
            self.arc_occupancy.resize(other.arc_occupancy.len(), 0);
        }
        for (i, o) in other.arc_occupancy.iter().enumerate() {
            self.arc_occupancy[i] += o;
        }
        for (k, v) in &other.opcode_density {
            *self.opcode_density.entry(k).or_insert(0) += v;
        }
        for (i, t) in other.cut_traffic.iter().enumerate() {
            self.cut(i, *t);
        }
        self.cycles = self.cycles.max(other.cycles);
        self.total_firings += other.total_firings;
    }

    /// Node indices with the highest firing counts, descending; ties break
    /// toward the lower node id so tables are deterministic.
    pub fn hottest_nodes(&self, k: usize) -> Vec<(usize, NodeStats)> {
        let mut rows: Vec<(usize, NodeStats)> = self.nodes.iter().copied().enumerate().collect();
        rows.sort_by(|a, b| b.1.firings.cmp(&a.1.firings).then(a.0.cmp(&b.0)));
        rows.truncate(k);
        rows
    }

    /// Node indices with the highest total stall counts, descending.
    pub fn worst_stalls(&self, k: usize) -> Vec<(usize, NodeStats)> {
        let mut rows: Vec<(usize, NodeStats)> = self.nodes.iter().copied().enumerate().collect();
        rows.sort_by(|a, b| b.1.stall_total().cmp(&a.1.stall_total()).then(a.0.cmp(&b.0)));
        rows.truncate(k);
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates_full_only_state() {
        assert!(ProfileLevel::Off < ProfileLevel::Counters);
        assert!(ProfileLevel::Counters < ProfileLevel::Full);
        assert_eq!(ProfileLevel::default(), ProfileLevel::Off);
        let p = EngineProfile::new("token", ProfileLevel::Counters, 4, 9);
        assert!(p.arc_occupancy.is_empty());
        let p = EngineProfile::new("token", ProfileLevel::Full, 4, 9);
        assert_eq!(p.arc_occupancy.len(), 9);
    }

    #[test]
    fn fire_stall_and_merge_accumulate() {
        let mut a = EngineProfile::new("lanes", ProfileLevel::Full, 3, 2);
        a.fire_n(1, 5);
        a.stall(0, StallCause::InputStarved);
        a.stall(0, StallCause::OutputBlocked);
        a.occupy(1, 4);
        a.opcode("add", 5);
        a.cut(0, 2);
        a.cycles = 10;

        let mut b = EngineProfile::new("lanes", ProfileLevel::Full, 3, 2);
        b.fire_n(1, 3);
        b.stall(0, StallCause::GateClosed);
        b.occupy(1, 1);
        b.opcode("add", 3);
        b.cut(1, 7);
        b.cycles = 12;

        a.merge(&b);
        assert_eq!(a.nodes[1].firings, 8);
        assert_eq!(a.total_firings, 8);
        assert_eq!(a.nodes[0].stall_total(), 3);
        assert_eq!(a.arc_occupancy[1], 5);
        assert_eq!(a.opcode_density["add"], 8);
        assert_eq!(a.cut_traffic, vec![2, 7]);
        assert_eq!(a.cycles, 12);
        assert_eq!(a.hottest_nodes(1)[0].0, 1);
        assert_eq!(a.worst_stalls(1)[0].0, 0);
    }
}
