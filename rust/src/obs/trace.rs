//! Deterministic event tracing: typed spans keyed by `(tenant, seq, engine)`
//! and timestamped in **virtual ticks + per-engine cycles**, never wall
//! clock. Two runs that make the same scheduling decisions therefore emit
//! byte-identical traces regardless of worker count, machine, or load —
//! pinned by the `obs_determinism_*` conformance properties.
//!
//! [`TraceBuf`] is a lock-striped bounded ring buffer. Recording NEVER
//! blocks progress and NEVER errors: when a stripe is full the oldest event
//! in that stripe is dropped and a global `dropped` counter is bumped, so
//! exports can always say how much history they are missing (DESIGN.md §12).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Typed span/instant kinds, in request-lifecycle order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Request admitted to a tenant queue (tick = admission tick).
    Admit,
    /// Request drafted into a dispatch batch (tick = dispatch tick).
    BatchForm,
    /// Engine lattice decision for a batch (detail = batch size).
    RouteSelect,
    /// First placement of a graph onto the fabric (cold path).
    Place,
    /// First compile of a graph for an engine (cold path).
    Compile,
    /// One request executed (cycles = engine cycles for its batch).
    Execute,
    /// Chaos: session checkpoint/restore migration (detail = instance).
    Migrate,
    /// Chaos: batch retry after an injected fault (detail = backoff ticks).
    Retry,
    /// Chaos: batch demoted down the engine lattice (detail = step).
    Demote,
    /// Chaos: warm-route eviction after a slot fault (detail = instance).
    Evict,
    /// Elastic: one instance drained, retopologized, and readmitted
    /// during a rolling repartition (detail = instance).
    Repartition,
    /// Elastic: a tenant promoted up the route lattice after a
    /// repartition made its graph fit (detail = tenant's queue demand).
    Promote,
}

impl SpanKind {
    pub const ALL: [SpanKind; 12] = [
        SpanKind::Admit,
        SpanKind::BatchForm,
        SpanKind::RouteSelect,
        SpanKind::Place,
        SpanKind::Compile,
        SpanKind::Execute,
        SpanKind::Migrate,
        SpanKind::Retry,
        SpanKind::Demote,
        SpanKind::Evict,
        SpanKind::Repartition,
        SpanKind::Promote,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::BatchForm => "batch_form",
            SpanKind::RouteSelect => "route_select",
            SpanKind::Place => "place",
            SpanKind::Compile => "compile",
            SpanKind::Execute => "execute",
            SpanKind::Migrate => "migrate",
            SpanKind::Retry => "retry",
            SpanKind::Demote => "demote",
            SpanKind::Evict => "evict",
            SpanKind::Repartition => "repartition",
            SpanKind::Promote => "promote",
        }
    }
}

/// One trace event. Every field is virtual (ticks, cycles, ids) — wall
/// clock is banned from the record path by construction and only attached
/// as an export-time sidecar (`obs::export`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub kind: SpanKind,
    /// Tenant id, or `TraceEvent::NO_TENANT` for tenant-less events.
    pub tenant: u32,
    /// Request sequence number within the tenant (0 for batch-level events).
    pub seq: u64,
    /// Virtual scheduler tick at which the event happened.
    pub tick: u64,
    /// Engine cycles attributed to the event (0 for instants).
    pub cycles: u64,
    /// Engine label ("sched", "placed", "lanes", "stream", ...).
    pub engine: &'static str,
    /// Kind-specific payload (batch size, backoff, instance id, ...).
    pub detail: u64,
}

impl TraceEvent {
    pub const NO_TENANT: u32 = u32::MAX;

    /// Total order used by [`TraceBuf::drain_sorted`]: every field
    /// participates, so the sorted stream is a pure function of the event
    /// multiset — stripe interleaving can never leak into exports.
    fn sort_key(&self) -> (u64, u32, u64, SpanKind, &'static str, u64, u64) {
        (
            self.tick,
            self.tenant,
            self.seq,
            self.kind,
            self.engine,
            self.cycles,
            self.detail,
        )
    }
}

const STRIPES: usize = 8;

/// Lock-striped bounded ring buffer of [`TraceEvent`]s.
///
/// Stripes are keyed by tenant so concurrent recorders for different
/// tenants rarely contend. Capacity is split evenly across stripes; each
/// stripe independently drops its oldest event on overflow.
#[derive(Debug)]
pub struct TraceBuf {
    stripes: Vec<Mutex<VecDeque<TraceEvent>>>,
    cap_per_stripe: usize,
    dropped: AtomicU64,
}

impl TraceBuf {
    /// Default total capacity (events) — plenty for a `--quick` serve run.
    pub const DEFAULT_CAPACITY: usize = 1 << 16;

    pub fn new(capacity: usize) -> Self {
        let cap_per_stripe = capacity.div_ceil(STRIPES).max(1);
        TraceBuf {
            stripes: (0..STRIPES).map(|_| Mutex::new(VecDeque::new())).collect(),
            cap_per_stripe,
            dropped: AtomicU64::new(0),
        }
    }

    fn stripe(&self, tenant: u32) -> &Mutex<VecDeque<TraceEvent>> {
        &self.stripes[tenant as usize % STRIPES]
    }

    /// Record one event. Never blocks progress on a full buffer: the
    /// stripe's oldest event is discarded and `dropped` incremented.
    pub fn record(&self, ev: TraceEvent) {
        let mut q = self.stripe(ev.tenant).lock().unwrap();
        if q.len() >= self.cap_per_stripe {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(ev);
    }

    /// Events discarded to overflow so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain all stripes and return the events in the canonical total
    /// order (see [`TraceEvent::sort_key`]). This is the only read path;
    /// exports and conformance tests both go through it.
    pub fn drain_sorted(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for s in &self.stripes {
            out.append(&mut s.lock().unwrap().drain(..).collect());
        }
        out.sort_by(|a, b| a.sort_key().cmp(&b.sort_key()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(tenant: u32, seq: u64, tick: u64) -> TraceEvent {
        TraceEvent {
            kind: SpanKind::Execute,
            tenant,
            seq,
            tick,
            cycles: 7,
            engine: "placed",
            detail: 0,
        }
    }

    #[test]
    fn drain_is_sorted_regardless_of_record_order() {
        let buf = TraceBuf::new(64);
        buf.record(ev(3, 2, 9));
        buf.record(ev(0, 5, 1));
        buf.record(ev(1, 0, 9));
        buf.record(ev(0, 4, 1));
        let evs = buf.drain_sorted();
        let keys: Vec<_> = evs.iter().map(|e| (e.tick, e.tenant, e.seq)).collect();
        assert_eq!(keys, vec![(1, 0, 4), (1, 0, 5), (9, 1, 0), (9, 3, 2)]);
        assert!(buf.is_empty());
        assert_eq!(buf.dropped(), 0);
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        let buf = TraceBuf::new(STRIPES); // one event per stripe
        buf.record(ev(0, 0, 0));
        buf.record(ev(0, 1, 1)); // same stripe: evicts seq 0
        assert_eq!(buf.dropped(), 1);
        let evs = buf.drain_sorted();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].seq, 1);
    }

    #[test]
    fn concurrent_recording_never_loses_under_capacity() {
        let buf = TraceBuf::new(1 << 12);
        std::thread::scope(|s| {
            for t in 0..4u32 {
                let buf = &buf;
                s.spawn(move || {
                    for i in 0..100 {
                        buf.record(ev(t, i, i));
                    }
                });
            }
        });
        assert_eq!(buf.len(), 400);
        assert_eq!(buf.dropped(), 0);
    }
}
