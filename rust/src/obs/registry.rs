//! Unified counter registry: one named-counter abstraction behind every
//! ad-hoc metrics family in the stack (`coordinator::Metrics`,
//! `serve::stats::ChaosStats`, `par::ParStats`, ...).
//!
//! A [`CounterSet`] is a fixed family of `AtomicU64` counters addressed by
//! compile-time index, with the index-to-name mapping carried alongside so
//! any report can render a family without knowing who owns it. Callers keep
//! their existing public snapshot shapes (`MetricsSnapshot`, `ParStats`,
//! `ChaosStats`) as thin views built from [`CounterSet::snapshot`]; the
//! duplicated per-struct atomic boilerplate lives here exactly once.
//!
//! Counters use `Ordering::Relaxed` throughout: every family in this stack
//! is monotone event counting, never synchronization.

use std::sync::atomic::{AtomicU64, Ordering};

/// A named family of monotone atomic counters.
///
/// Indices are compile-time constants owned by the embedding module (e.g.
/// `coordinator::metric::SUBMITTED`); `names[i]` is the export label of
/// counter `i`.
#[derive(Debug)]
pub struct CounterSet {
    family: &'static str,
    names: &'static [&'static str],
    vals: Box<[AtomicU64]>,
}

impl CounterSet {
    /// New all-zero family. `names.len()` fixes the counter count for life.
    pub fn new(family: &'static str, names: &'static [&'static str]) -> Self {
        let vals: Box<[AtomicU64]> = (0..names.len()).map(|_| AtomicU64::new(0)).collect();
        CounterSet {
            family,
            names,
            vals,
        }
    }

    pub fn family(&self) -> &'static str {
        self.family
    }

    pub fn names(&self) -> &'static [&'static str] {
        self.names
    }

    /// Add `n` to counter `idx`. Panics on out-of-range index (a programming
    /// error: indices are compile-time constants).
    pub fn add(&self, idx: usize, n: u64) {
        self.vals[idx].fetch_add(n, Ordering::Relaxed);
    }

    /// Increment counter `idx` by one.
    pub fn incr(&self, idx: usize) {
        self.add(idx, 1);
    }

    /// Current value of counter `idx`.
    pub fn get(&self, idx: usize) -> u64 {
        self.vals[idx].load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the whole family. Each counter is read
    /// individually (no cross-counter atomicity — same contract the ad-hoc
    /// snapshot structs always had).
    pub fn snapshot(&self) -> FamilySnapshot {
        FamilySnapshot {
            family: self.family,
            names: self.names,
            vals: self.vals.iter().map(|v| v.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// Immutable point-in-time view of one [`CounterSet`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FamilySnapshot {
    pub family: &'static str,
    pub names: &'static [&'static str],
    pub vals: Vec<u64>,
}

impl FamilySnapshot {
    /// Value by export label; 0 for unknown names (additive-schema friendly).
    pub fn get(&self, name: &str) -> u64 {
        self.names
            .iter()
            .position(|n| *n == name)
            .map_or(0, |i| self.vals[i])
    }

    /// `(name, value)` rows in declaration order.
    pub fn rows(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.names.iter().copied().zip(self.vals.iter().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const NAMES: [&str; 3] = ["alpha", "beta", "gamma"];

    #[test]
    fn counters_accumulate_and_snapshot() {
        let c = CounterSet::new("test", &NAMES);
        c.incr(0);
        c.add(1, 41);
        c.incr(1);
        assert_eq!(c.get(0), 1);
        assert_eq!(c.get(1), 42);
        assert_eq!(c.get(2), 0);
        let s = c.snapshot();
        assert_eq!(s.family, "test");
        assert_eq!(s.vals, vec![1, 42, 0]);
        assert_eq!(s.get("beta"), 42);
        assert_eq!(s.get("missing"), 0);
        assert_eq!(
            s.rows().collect::<Vec<_>>(),
            vec![("alpha", 1), ("beta", 42), ("gamma", 0)]
        );
    }

    #[test]
    fn shared_across_threads() {
        let c = CounterSet::new("t", &NAMES);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        c.incr(2);
                    }
                });
            }
        });
        assert_eq!(c.get(2), 4000);
    }
}
