//! `obs` — deterministic observability for the whole execution stack.
//!
//! Four pieces (DESIGN.md §12):
//!
//! * [`trace`] — lock-striped bounded ring buffer of typed
//!   [`SpanKind`] events, timestamped in virtual ticks + engine cycles
//!   (never wall clock on the record path), so traces are byte-identical
//!   across worker counts.
//! * [`prof`] — per-node/per-arc profiling hooks inside `TokenSim`,
//!   `LaneSim` and `StreamSession` behind a zero-cost-when-off
//!   [`ProfileLevel`], with stall attribution
//!   {input-starved, output-blocked, gate-closed}.
//! * [`registry`] — one named-counter abstraction unifying the stack's
//!   four ad-hoc counter families.
//! * [`export`] + [`flight`] — Chrome `trace_event` / `OBS_9.json`
//!   serialization and the chaos-path flight recorder.

pub mod export;
pub mod flight;
pub mod prof;
pub mod registry;
pub mod trace;

pub use export::{chrome_trace, events_json, obs_json, ObsArtifact};
pub use flight::FlightRecorder;
pub use prof::{EngineProfile, NodeStats, ProfileLevel, StallCause};
pub use registry::{CounterSet, FamilySnapshot};
pub use trace::{SpanKind, TraceBuf, TraceEvent};
