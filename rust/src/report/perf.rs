//! The first-class perf harness behind the `bench` CLI subcommand.
//!
//! Runs the **seven-benchmark suite** — the paper's six loop-schema
//! benchmarks plus the pipelineable SAXPY workload — as a batch of
//! independent items under four engines:
//!
//! * `scalar`  — the run-to-completion baseline: one whole-graph
//!   [`TokenSim`](crate::sim::TokenSim) run per item (what every PR
//!   before the lane engine shipped as the batch path's inner loop).
//! * `streamed` — the resident [`crate::sim::StreamSession`]
//!   admitting the batch as successive waves.
//! * `lanes`   — the lane-vectorized engine: the batch in lockstep
//!   multi-word chunks of up to [`MAX_LANES`](crate::sim::MAX_LANES)
//!   items through one compiled, superinstruction-fused program
//!   ([`run_batch_lanes_prog`](crate::coordinator::run_batch_lanes_prog)).
//!   The program is compiled **outside** the timed loop — that is the
//!   serve tier's steady state, where the session cache holds the
//!   compiled program warm — and `PerfCfg::fuse` (CLI `--no-fuse`)
//!   selects fused vs. unfused compilation so the two can be A/B'd
//!   from the same binary.
//! * `sstream-par` — the serialized-stream batch split into
//!   contiguous wave spans across a [`crate::par::Executor`]
//!   work-stealing pool
//!   ([`run_batch_sstream_par`](crate::coordinator::run_batch_sstream_par)).
//!
//! Timing is hand-rolled `std::time::Instant` through the crate's own
//! criterion-style loop ([`crate::util::bench`]); the multi-worker
//! engine reports its pool's busy-time delta through
//! [`bench::run_timed`](crate::util::bench::run_timed) so wall and CPU
//! cost stay distinct. No external deps.
//! Every engine's outputs are verified against the benchmark's software
//! reference before its numbers are reported, so a wrong-but-fast
//! engine can never seed the trajectory.
//!
//! The results serialize to a hand-rolled JSON file (`BENCH_<k>.json`,
//! schema `dataflow-accel-bench/v1`) so future PRs have a throughput
//! trajectory to regress against; EXPERIMENTS.md documents how to run
//! and read it, and CI's `bench-smoke` job uploads a reduced-iteration
//! run per push.

use crate::bench_defs::{self, BenchId};
use crate::coordinator::{run_batch_lanes_prog, run_batch_sstream_par};
use crate::dfg::Word;
use crate::par::Executor;
use crate::sim::{
    self, overlap_safe, run_token, Program, SimConfig, SimOutcome, WaveInput, MAX_LANES,
};
use crate::util::bench::{self as timing, BenchCfg, IterCost};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Harness configuration (CLI flags of the `bench` subcommand).
#[derive(Debug, Clone, Copy)]
pub struct PerfCfg {
    /// Batch items per benchmark (256 = one full multi-word lane chunk).
    pub items: usize,
    /// Workload size per item.
    pub n: usize,
    pub seed: u64,
    /// Reduced iteration counts (the CI smoke job).
    pub quick: bool,
    /// Compile the lane engine's program with superinstruction fusion
    /// (the default; `--no-fuse` clears it for A/B comparison runs).
    pub fuse: bool,
}

impl PerfCfg {
    pub fn new(items: usize, n: usize, seed: u64, quick: bool) -> Self {
        PerfCfg {
            items,
            n,
            seed,
            quick,
            fuse: true,
        }
    }

    fn timing(&self) -> BenchCfg {
        if self.quick {
            BenchCfg {
                warmup_iters: 0,
                samples: 2,
                iters_per_sample: 1,
            }
        } else {
            BenchCfg {
                warmup_iters: 1,
                samples: 7,
                iters_per_sample: 1,
            }
        }
    }
}

/// One engine's measurement on one benchmark's batch.
#[derive(Debug, Clone)]
pub struct EngineResult {
    pub engine: &'static str,
    /// Median wall time for the whole batch, nanoseconds.
    pub median_ns: f64,
    /// Median per-iteration busy time summed over every worker that
    /// executed part of the batch (equals wall for single-threaded
    /// engines; see [`crate::util::bench::Measurement::busy_ns`]).
    pub busy_ns: f64,
    /// Workers that contributed to `busy_ns` (1 for the serial engines).
    pub workers: usize,
    pub tokens_out: u64,
    pub firings: u64,
    /// All items' outputs matched the software reference.
    pub verified: bool,
}

impl EngineResult {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_out as f64 / (self.median_ns.max(1.0) * 1e-9)
    }

    pub fn firings_per_sec(&self) -> f64 {
        self.firings as f64 / (self.median_ns.max(1.0) * 1e-9)
    }

    /// Pool utilization: `busy / (wall × workers)`; ≈1.0 when serial.
    pub fn cpu_util(&self) -> f64 {
        self.busy_ns / (self.median_ns.max(1.0) * self.workers.max(1) as f64)
    }
}

/// One benchmark's row: the same batch under every engine.
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub name: String,
    /// Acyclic unit-rate — the lane engine's topo fast path applies
    /// (and the streamed engine may overlap waves).
    pub pipelineable: bool,
    pub items: usize,
    /// Widest lane chunk the batch actually occupied
    /// (`items.min(MAX_LANES)`).
    pub width: usize,
    /// Graph nodes swallowed into fused superinstruction chains by the
    /// lane engine's compiled program (0 when fusion is off or the
    /// graph takes the cyclic snapshot schedule).
    pub fused_nodes: usize,
    /// Fused chains in that program.
    pub chains: usize,
    pub engines: Vec<EngineResult>,
}

impl BenchRow {
    pub fn engine(&self, name: &str) -> Option<&EngineResult> {
        self.engines.iter().find(|e| e.engine == name)
    }

    /// Wall-time speedup of `engine` over the scalar baseline.
    pub fn speedup(&self, engine: &str) -> f64 {
        match (self.engine("scalar"), self.engine(engine)) {
            (Some(s), Some(e)) => s.median_ns / e.median_ns.max(1.0),
            _ => 1.0,
        }
    }
}

/// One benchmark's batch: per-item configs and expected output streams.
struct Batch {
    name: String,
    pipelineable: bool,
    cfgs: Vec<SimConfig>,
    waves: Vec<WaveInput>,
    expects: Vec<BTreeMap<String, Vec<Word>>>,
    budget: u64,
    graph: crate::dfg::Graph,
}

fn bench_batch(b: BenchId, cfg: &PerfCfg) -> Batch {
    let wls = bench_defs::wave_workloads(b, cfg.items, cfg.n, cfg.seed);
    let graph = bench_defs::build(b);
    Batch {
        name: b.slug().to_string(),
        pipelineable: overlap_safe(&graph),
        cfgs: wls.iter().map(|w| w.sim_config()).collect(),
        waves: wls.iter().map(|w| w.inject.clone()).collect(),
        expects: wls.iter().map(|w| w.expect.clone()).collect(),
        budget: wls.iter().map(|w| w.max_cycles).sum(),
        graph,
    }
}

fn saxpy_batch(cfg: &PerfCfg) -> Batch {
    let graph = bench_defs::saxpy::build();
    let pairs = bench_defs::saxpy::waves(cfg.items, cfg.n, cfg.seed);
    let budget = 1_000_000u64.saturating_mul(cfg.items.max(1) as u64);
    Batch {
        name: "saxpy".to_string(),
        pipelineable: overlap_safe(&graph),
        cfgs: pairs
            .iter()
            .map(|(w, _)| {
                let mut c = SimConfig::new();
                for (p, s) in w {
                    c = c.inject(p, s.clone());
                }
                c
            })
            .collect(),
        waves: pairs.iter().map(|(w, _)| w.clone()).collect(),
        expects: pairs
            .iter()
            .map(|(_, z)| BTreeMap::from([("z".to_string(), z.clone())]))
            .collect(),
        budget,
        graph,
    }
}

fn summarize(
    engine: &'static str,
    m: &timing::Measurement,
    outs: &[SimOutcome],
    expects: &[BTreeMap<String, Vec<Word>>],
) -> EngineResult {
    let tokens_out = outs
        .iter()
        .map(|o| o.outputs.values().map(|v| v.len() as u64).sum::<u64>())
        .sum();
    let firings = outs.iter().map(|o| o.firings).sum();
    let mut verified = outs.len() == expects.len();
    for (o, want) in outs.iter().zip(expects) {
        verified &= want.iter().all(|(port, stream)| o.stream(port) == stream.as_slice());
    }
    EngineResult {
        engine,
        median_ns: m.median_ns,
        busy_ns: m.busy_ns,
        workers: m.workers,
        tokens_out,
        firings,
        verified,
    }
}

fn measure_batch(batch: &Batch, cfg: &PerfCfg) -> BenchRow {
    let timing_cfg = cfg.timing();
    let g = &batch.graph;

    // Run-to-completion scalar baseline: one TokenSim walk per item.
    let scalar_outs: Vec<SimOutcome> = batch.cfgs.iter().map(|c| run_token(g, c)).collect();
    let m = timing::run(&format!("{}/scalar", batch.name), timing_cfg, || {
        batch.cfgs.iter().map(|c| run_token(g, c)).collect::<Vec<_>>()
    });
    let scalar = summarize("scalar", &m, &scalar_outs, &batch.expects);

    // Streamed: the whole batch as successive waves through one
    // resident session.
    let (stream_outs, _) = sim::run_stream(g, &batch.waves, batch.budget);
    let m = timing::run(&format!("{}/streamed", batch.name), timing_cfg, || {
        sim::run_stream(g, &batch.waves, batch.budget)
    });
    let streamed = summarize("streamed", &m, &stream_outs, &batch.expects);

    // Lanes: lockstep multi-word chunks (up to MAX_LANES items each)
    // through one compiled program. Compilation happens once, outside
    // the timed closure: the serve tier's warm path amortizes it the
    // same way through the session cache, and keeping it out of the
    // loop is what lets fused vs. unfused runs compare execution cost
    // rather than compile cost.
    let prog = if cfg.fuse {
        Program::compile(g)
    } else {
        Program::compile_unfused(g)
    };
    let (lane_outs, _) = run_batch_lanes_prog(g, &prog, &batch.cfgs);
    let m = timing::run(&format!("{}/lanes", batch.name), timing_cfg, || {
        run_batch_lanes_prog(g, &prog, &batch.cfgs)
    });
    let lanes = summarize("lanes", &m, &lane_outs, &batch.expects);

    // Parallel serialized stream: contiguous wave spans across the
    // work-stealing pool. Busy time is the executor's stats delta
    // around each iteration — never inferred from wall time.
    let exec = Executor::new(Executor::available_parallelism().min(4));
    let par_outs = run_batch_sstream_par(g, &batch.cfgs, &exec);
    let m = timing::run_timed(&format!("{}/sstream-par", batch.name), timing_cfg, || {
        let before = exec.stats();
        let outs = run_batch_sstream_par(g, &batch.cfgs, &exec);
        let cost = IterCost {
            busy_ns: exec.stats().busy_ns.saturating_sub(before.busy_ns),
            workers: exec.workers(),
        };
        (outs, cost)
    });
    let sstream_par = summarize("sstream-par", &m, &par_outs, &batch.expects);

    BenchRow {
        name: batch.name.clone(),
        pipelineable: batch.pipelineable,
        items: batch.cfgs.len(),
        width: batch.cfgs.len().min(MAX_LANES),
        fused_nodes: prog.fused_nodes(),
        chains: prog.n_chains(),
        engines: vec![scalar, streamed, lanes, sstream_par],
    }
}

/// Run the whole suite (six paper benchmarks + SAXPY) under all four
/// engines.
pub fn run_suite(cfg: &PerfCfg) -> Vec<BenchRow> {
    let mut rows = Vec::new();
    for b in BenchId::ALL {
        rows.push(measure_batch(&bench_batch(b, cfg), cfg));
    }
    rows.push(measure_batch(&saxpy_batch(cfg), cfg));
    rows
}

/// Floor applied to each per-row speedup before it enters the
/// geometric mean. A degenerate ratio — zero or negative from timer
/// quantization on sub-resolution quick runs, or non-finite from a
/// zeroed denominator — would otherwise poison the whole summary
/// (ln(0) = -∞ drags the mean to ~0, NaN makes it NaN), and that
/// summary is the number CI regresses against.
pub const SPEEDUP_FLOOR: f64 = 0.01;

/// Geometric mean of the lane-engine speedup over the scalar baseline,
/// across `rows` filtered by `pipelineable_only`. Returns 1.0 when the
/// filter selects nothing; always finite and ≥ [`SPEEDUP_FLOOR`].
pub fn geomean_lane_speedup(rows: &[BenchRow], pipelineable_only: bool) -> f64 {
    let speedups: Vec<f64> = rows
        .iter()
        .filter(|r| !pipelineable_only || r.pipelineable)
        .map(|r| {
            let s = r.speedup("lanes");
            if s.is_finite() {
                s.max(SPEEDUP_FLOOR)
            } else {
                SPEEDUP_FLOOR
            }
        })
        .collect();
    if speedups.is_empty() {
        return 1.0;
    }
    let log_sum: f64 = speedups.iter().map(|s| s.ln()).sum();
    (log_sum / speedups.len() as f64).exp()
}

fn json_escape(s: &str) -> String {
    // Benchmark names are ASCII slugs, but stay safe anyway.
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Serialize the suite results (schema `dataflow-accel-bench/v1`).
pub fn to_json(rows: &[BenchRow], cfg: &PerfCfg) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"dataflow-accel-bench/v1\",\n");
    writeln!(out, "  \"quick\": {},", cfg.quick).unwrap();
    writeln!(out, "  \"items\": {},", cfg.items).unwrap();
    writeln!(out, "  \"n\": {},", cfg.n).unwrap();
    writeln!(out, "  \"seed\": {},", cfg.seed).unwrap();
    writeln!(out, "  \"fuse\": {},", cfg.fuse).unwrap();
    out.push_str("  \"benchmarks\": [\n");
    for (ri, r) in rows.iter().enumerate() {
        let row_comma = if ri + 1 < rows.len() { "," } else { "" };
        out.push_str("    {\n");
        writeln!(out, "      \"name\": \"{}\",", json_escape(&r.name)).unwrap();
        writeln!(out, "      \"pipelineable\": {},", r.pipelineable).unwrap();
        writeln!(out, "      \"items\": {},", r.items).unwrap();
        writeln!(out, "      \"width\": {},", r.width).unwrap();
        writeln!(out, "      \"fused_nodes\": {},", r.fused_nodes).unwrap();
        writeln!(out, "      \"chains\": {},", r.chains).unwrap();
        out.push_str("      \"engines\": [\n");
        for (ei, e) in r.engines.iter().enumerate() {
            let comma = if ei + 1 < r.engines.len() { "," } else { "" };
            let speedup = r.speedup(e.engine);
            out.push_str("        {\n");
            writeln!(out, "          \"engine\": \"{}\",", e.engine).unwrap();
            writeln!(out, "          \"median_ns\": {:.0},", e.median_ns).unwrap();
            writeln!(out, "          \"busy_ns\": {:.0},", e.busy_ns).unwrap();
            writeln!(out, "          \"workers\": {},", e.workers).unwrap();
            writeln!(out, "          \"cpu_util\": {:.3},", e.cpu_util()).unwrap();
            writeln!(out, "          \"tokens_out\": {},", e.tokens_out).unwrap();
            writeln!(out, "          \"firings\": {},", e.firings).unwrap();
            let tps = e.tokens_per_sec();
            let fps = e.firings_per_sec();
            writeln!(out, "          \"tokens_per_sec\": {tps:.1},").unwrap();
            writeln!(out, "          \"firings_per_sec\": {fps:.1},").unwrap();
            writeln!(out, "          \"speedup_vs_scalar\": {speedup:.3},").unwrap();
            writeln!(out, "          \"verified\": {}", e.verified).unwrap();
            writeln!(out, "        }}{comma}").unwrap();
        }
        out.push_str("      ]\n");
        writeln!(out, "    }}{row_comma}").unwrap();
    }
    out.push_str("  ],\n");
    let all = geomean_lane_speedup(rows, false);
    let pipe = geomean_lane_speedup(rows, true);
    writeln!(out, "  \"geomean_lane_speedup\": {all:.3},").unwrap();
    writeln!(out, "  \"geomean_lane_speedup_pipelineable\": {pipe:.3}").unwrap();
    out.push_str("}\n");
    out
}

/// Human-readable summary table (the `bench` subcommand's stdout).
pub fn render_table(rows: &[BenchRow]) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "{:<12} {:>5} {:>5} {:>5} {:<11} {:>12} {:>14} {:>14} {:>8} {:>4} {:>5} {:>9}",
        "benchmark",
        "items",
        "width",
        "fused",
        "engine",
        "median",
        "tokens/s",
        "firings/s",
        "speedup",
        "wkr",
        "util",
        "verified"
    )
    .unwrap();
    for r in rows {
        for e in &r.engines {
            writeln!(
                out,
                "{:<12} {:>5} {:>5} {:>5} {:<11} {:>12} {:>14.0} {:>14.0} {:>7.2}x {:>4} {:>5.2} {:>9}",
                r.name,
                r.items,
                r.width,
                r.fused_nodes,
                e.engine,
                timing::fmt_ns(e.median_ns),
                e.tokens_per_sec(),
                e.firings_per_sec(),
                r.speedup(e.engine),
                e.workers,
                e.cpu_util(),
                if e.verified { "yes" } else { "NO" }
            )
            .unwrap();
        }
    }
    let all = geomean_lane_speedup(rows, false);
    let pipe = geomean_lane_speedup(rows, true);
    writeln!(
        out,
        "geomean lane speedup vs scalar: {all:.2}x (all), {pipe:.2}x (pipelineable)"
    )
    .unwrap();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> PerfCfg {
        PerfCfg::new(3, 3, 11, true)
    }

    #[test]
    fn suite_covers_seven_benchmarks_and_verifies() {
        let rows = run_suite(&tiny_cfg());
        assert_eq!(rows.len(), BenchId::ALL.len() + 1);
        assert!(rows.iter().any(|r| r.name == "saxpy"));
        for r in &rows {
            assert_eq!(r.engines.len(), 4, "{}", r.name);
            for e in &r.engines {
                assert!(e.verified, "{}/{} failed verification", r.name, e.engine);
                assert!(e.tokens_out > 0, "{}/{}", r.name, e.engine);
                assert!(e.median_ns > 0.0, "{}/{}", r.name, e.engine);
                assert!(e.workers >= 1, "{}/{}", r.name, e.engine);
            }
            // The parallel engine reproduces the serialized-stream
            // results token for token (same verification oracle) and
            // reports its pool size.
            let par = r.engine("sstream-par").unwrap();
            let streamed = r.engine("streamed").unwrap();
            assert_eq!(par.tokens_out, streamed.tokens_out, "{}", r.name);
        }
        let saxpy = rows.iter().find(|r| r.name == "saxpy").unwrap();
        assert!(saxpy.pipelineable);
        // SAXPY's mul → fifo → add spine fuses into one chain.
        assert!(saxpy.chains >= 1, "saxpy should fuse: {saxpy:?}");
        assert!(saxpy.fused_nodes >= 2);
        for b in BenchId::ALL {
            let row = rows.iter().find(|r| r.name == b.slug()).unwrap();
            assert!(!row.pipelineable, "{} is a loop schema", b.slug());
            // Loop schemas take the cyclic snapshot schedule: no exec
            // list, no chains.
            assert_eq!(row.chains, 0, "{}", b.slug());
            assert_eq!(row.width, row.items.min(MAX_LANES));
        }
    }

    #[test]
    fn no_fuse_runs_the_same_suite_without_chains() {
        let mut cfg = tiny_cfg();
        cfg.fuse = false;
        let rows = run_suite(&cfg);
        for r in &rows {
            assert_eq!(r.chains, 0, "{}", r.name);
            assert_eq!(r.fused_nodes, 0, "{}", r.name);
            for e in &r.engines {
                assert!(e.verified, "{}/{} failed verification", r.name, e.engine);
            }
        }
        let json = to_json(&rows, &cfg);
        assert!(json.contains("\"fuse\": false"));
    }

    #[test]
    fn json_is_well_formed_enough_to_grep() {
        let cfg = tiny_cfg();
        let rows = run_suite(&cfg);
        let json = to_json(&rows, &cfg);
        assert!(json.starts_with("{\n"));
        assert!(json.ends_with("}\n"));
        assert!(json.contains("\"schema\": \"dataflow-accel-bench/v1\""));
        assert!(json.contains("\"geomean_lane_speedup_pipelineable\""));
        assert!(json.contains("\"fuse\": true"));
        assert_eq!(json.matches("\"width\":").count(), rows.len());
        assert_eq!(json.matches("\"fused_nodes\":").count(), rows.len());
        assert_eq!(json.matches("\"chains\":").count(), rows.len());
        assert_eq!(json.matches("\"engine\": \"lanes\"").count(), rows.len());
        assert_eq!(json.matches("\"engine\": \"sstream-par\"").count(), rows.len());
        assert_eq!(json.matches("\"cpu_util\":").count(), rows.len() * 4);
        // Balanced braces/brackets (a cheap structural check; CI's
        // smoke job runs a real JSON parser over the artifact).
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        // No bare NaN/inf can reach the file.
        assert!(!json.contains("NaN") && !json.contains("inf"));
    }

    #[test]
    fn table_renders_every_engine_row() {
        let cfg = tiny_cfg();
        let rows = run_suite(&cfg);
        let t = render_table(&rows);
        for r in &rows {
            assert!(t.contains(&r.name));
        }
        assert!(t.contains("scalar") && t.contains("streamed") && t.contains("lanes"));
        assert!(t.contains("sstream-par") && t.contains("util"));
        assert!(t.contains("geomean lane speedup"));
    }

    #[test]
    fn geomean_handles_empty_filters() {
        assert_eq!(geomean_lane_speedup(&[], true), 1.0);
    }

    fn engine_at(engine: &'static str, median_ns: f64) -> EngineResult {
        EngineResult {
            engine,
            median_ns,
            busy_ns: median_ns,
            workers: 1,
            tokens_out: 1,
            firings: 1,
            verified: true,
        }
    }

    #[test]
    fn degenerate_speedups_cannot_poison_the_geomean() {
        // A zero scalar median (timer quantization on sub-resolution
        // quick runs) yields a 0.0 speedup; before the SPEEDUP_FLOOR
        // clamp the geomean collapsed to ~1e-9 and that near-zero
        // summary was written straight into the BENCH json CI gates on.
        let row = BenchRow {
            name: "degenerate".into(),
            pipelineable: true,
            items: 1,
            width: 1,
            fused_nodes: 0,
            chains: 0,
            engines: vec![engine_at("scalar", 0.0), engine_at("lanes", 10.0)],
        };
        let g = geomean_lane_speedup(&[row], true);
        assert!(g.is_finite(), "geomean must stay finite, got {g}");
        assert!(g >= SPEEDUP_FLOOR, "geomean {g} fell below the floor");
    }
}
