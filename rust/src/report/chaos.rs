//! The chaos-run verdict: `CHAOS_8.json` (schema
//! `dataflow-accel-chaos/v1`), written by `serve --chaos` **only**
//! when the zero-lost-requests gate holds. The CLI refuses to write
//! the file otherwise, so the artifact's existence is itself the
//! claim; the JSON carries the evidence (per-kind fault census,
//! accounting, digest-match verdict, recovery counters) so CI can
//! re-assert it without re-running.

use crate::fabric::FaultPlan;
use crate::report::obs::format_event;
use crate::serve::ChaosOutcome;
use std::fmt::Write as _;

/// Everything the chaos gate checks, precomputed so the CLI and the
/// JSON writer cannot disagree about what passed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosGate {
    /// ≥ 1 slot failure, ≥ 1 bus failure, ≥ 1 outage injected.
    pub all_fault_kinds: bool,
    /// No request vanished: `lost == 0` for every tenant.
    pub zero_lost: bool,
    /// `completed + shed == submitted` globally.
    pub accounting_exact: bool,
    /// Every completed request's output digest is byte-identical to
    /// the fault-free baseline's, and both runs completed the same
    /// request set.
    pub digest_match: bool,
    /// When `digest_match` is false: the first `(tenant, seq)` — in
    /// key order — whose digest differs (or exists on one side only),
    /// so the verdict can dump that request's flight-recorder timeline
    /// instead of a bare "digests diverged".
    pub first_divergence: Option<(usize, usize)>,
}

impl ChaosGate {
    /// Evaluate the gate over a chaos run and its fault-free baseline
    /// (same profile, same options, [`FaultPlan::empty`]).
    pub fn check(plan: &FaultPlan, faulted: &ChaosOutcome, baseline: &ChaosOutcome) -> Self {
        let c = plan.counts();
        let g = &faulted.report.global;
        let first_divergence = first_divergence(faulted, baseline);
        ChaosGate {
            all_fault_kinds: c.slot >= 1 && c.bus >= 1 && c.outage >= 1,
            zero_lost: faulted.report.tenants.iter().all(|t| t.lost() == 0) && g.lost() == 0,
            accounting_exact: g.completed + g.shed() == g.submitted,
            digest_match: first_divergence.is_none(),
            first_divergence,
        }
    }

    pub fn passed(&self) -> bool {
        self.all_fault_kinds && self.zero_lost && self.accounting_exact && self.digest_match
    }

    /// The gates that failed, for the CLI's refusal message.
    pub fn failures(&self) -> Vec<&'static str> {
        let mut v = Vec::new();
        if !self.all_fault_kinds {
            v.push("fault plan missing a slot/bus/outage event");
        }
        if !self.zero_lost {
            v.push("requests were lost (neither completed nor shed)");
        }
        if !self.accounting_exact {
            v.push("completed + shed != submitted");
        }
        if !self.digest_match {
            v.push("output digests diverge from the fault-free baseline");
        }
        v
    }
}

/// First `(tenant, seq)` — in `BTreeMap` key order — whose output
/// digest differs between the two runs, or which completed in one run
/// but not the other. `None` when the maps are identical.
fn first_divergence(faulted: &ChaosOutcome, baseline: &ChaosOutcome) -> Option<(usize, usize)> {
    let f = &faulted.output_digests;
    let b = &baseline.output_digests;
    // Union of both key sets, sorted, so a request that completed in
    // only one run still surfaces in true key order.
    f.keys()
        .chain(b.keys())
        .copied()
        .collect::<std::collections::BTreeSet<(usize, usize)>>()
        .into_iter()
        .find(|k| f.get(k) != b.get(k))
}

/// Serialize the chaos verdict (schema `dataflow-accel-chaos/v1`).
/// Callers gate on [`ChaosGate::passed`] before writing this to disk;
/// the serializer itself is total so tests can render failing gates.
pub fn to_json(
    gate: &ChaosGate,
    plan: &FaultPlan,
    faulted: &ChaosOutcome,
    seed: u64,
    quick: bool,
) -> String {
    let counts = plan.counts();
    let g = &faulted.report.global;
    let c = &faulted.chaos;
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"dataflow-accel-chaos/v1\",\n");
    writeln!(out, "  \"seed\": {seed},").unwrap();
    writeln!(out, "  \"quick\": {quick},").unwrap();
    writeln!(out, "  \"passed\": {},", gate.passed()).unwrap();
    writeln!(out, "  \"digest_match\": {},", gate.digest_match).unwrap();
    writeln!(out, "  \"submitted\": {},", g.submitted).unwrap();
    writeln!(out, "  \"completed\": {},", g.completed).unwrap();
    writeln!(out, "  \"shed\": {},", g.shed()).unwrap();
    writeln!(out, "  \"lost\": {},", g.lost()).unwrap();
    writeln!(out, "  \"verified\": {},", g.verified).unwrap();
    writeln!(out, "  \"ticks\": {},", faulted.report.ticks).unwrap();
    out.push_str("  \"plan\": {\n");
    writeln!(out, "    \"events\": {},", plan.events().len()).unwrap();
    writeln!(out, "    \"slot_fails\": {},", counts.slot).unwrap();
    writeln!(out, "    \"bus_fails\": {},", counts.bus).unwrap();
    writeln!(out, "    \"outages\": {},", counts.outage).unwrap();
    writeln!(out, "    \"repairs\": {}", counts.repair).unwrap();
    out.push_str("  },\n");
    out.push_str("  \"recovery\": {\n");
    writeln!(out, "    \"faults_injected\": {},", c.faults_injected()).unwrap();
    writeln!(out, "    \"migrations\": {},", c.migrations).unwrap();
    writeln!(out, "    \"rescued_waves\": {},", c.rescued_waves).unwrap();
    writeln!(out, "    \"retries\": {},", c.retries).unwrap();
    writeln!(out, "    \"demotions\": {},", c.demotions).unwrap();
    writeln!(out, "    \"route_invalidations\": {}", c.route_invalidations).unwrap();
    out.push_str("  },\n");
    writeln!(out, "  \"requests_digested\": {}", faulted.output_digests.len()).unwrap();
    out.push_str("}\n");
    out
}

/// The human verdict line the CLI prints alongside the table.
pub fn chaos_summary(gate: &ChaosGate, faulted: &ChaosOutcome) -> String {
    let c = &faulted.chaos;
    let mut out = String::new();
    writeln!(
        out,
        "chaos gate: {} | {} fault(s) injected, {} request(s) digest-checked \
         against the fault-free baseline",
        if gate.passed() { "PASS" } else { "FAIL" },
        c.faults_injected(),
        faulted.output_digests.len()
    )
    .unwrap();
    for f in gate.failures() {
        writeln!(out, "  gate failure: {f}").unwrap();
    }
    if let Some((tenant, seq)) = gate.first_divergence {
        writeln!(
            out,
            "  first divergence: tenant {tenant} seq {seq} — flight-recorder tail for \
             tenant {tenant}:"
        )
        .unwrap();
        let tail = faulted.flight.timeline(tenant as u32);
        if tail.is_empty() {
            writeln!(out, "    (flight recorder empty for this tenant)").unwrap();
        }
        for ev in &tail {
            writeln!(out, "    {}", format_event(ev)).unwrap();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{fairness_profile, run_profile_chaos, ServeOptions};

    fn runs() -> (FaultPlan, ChaosOutcome, ChaosOutcome) {
        let p = fairness_profile(1, 5, 17);
        let opts = ServeOptions::default();
        let plan = FaultPlan::seeded(17, opts.pool_size);
        let baseline = run_profile_chaos(&p, &opts, &FaultPlan::empty());
        let faulted = run_profile_chaos(&p, &opts, &plan);
        (plan, faulted, baseline)
    }

    #[test]
    fn gate_passes_on_a_seeded_run_and_json_carries_the_verdict() {
        let (plan, faulted, baseline) = runs();
        let gate = ChaosGate::check(&plan, &faulted, &baseline);
        assert!(gate.passed(), "{:?}", gate.failures());
        let json = to_json(&gate, &plan, &faulted, 17, true);
        assert!(json.contains("\"schema\": \"dataflow-accel-chaos/v1\""));
        assert!(json.contains("\"passed\": true"));
        assert!(json.contains("\"digest_match\": true"));
        assert!(json.contains("\"lost\": 0"));
        assert!(!json.contains("\"faults_injected\": 0"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        let line = chaos_summary(&gate, &faulted);
        assert!(line.contains("PASS"), "{line}");
    }

    #[test]
    fn gate_fails_loudly_when_digests_or_census_break() {
        let (plan, faulted, baseline) = runs();
        // An empty plan fails the census gate...
        let empty_gate = ChaosGate::check(&FaultPlan::empty(), &faulted, &baseline);
        assert!(!empty_gate.passed());
        assert!(!empty_gate.all_fault_kinds);
        // ...and a doctored baseline fails the digest gate.
        let mut wrong = ChaosGate::check(&plan, &faulted, &baseline);
        wrong.digest_match = false;
        assert!(!wrong.passed());
        let line = chaos_summary(&wrong, &faulted);
        assert!(line.contains("FAIL"), "{line}");
        assert!(line.contains("diverge"), "{line}");
        let json = to_json(&wrong, &plan, &faulted, 17, true);
        assert!(json.contains("\"passed\": false"));
    }

    #[test]
    fn digest_gate_failure_names_the_divergence_and_dumps_its_timeline() {
        let (plan, mut faulted, baseline) = runs();
        // Deliberately perturb one output digest: the gate must fail,
        // name exactly this (tenant, seq), and dump that tenant's
        // flight-recorder tail.
        let (&key, &val) = faulted.output_digests.iter().next().unwrap();
        faulted.output_digests.insert(key, val ^ 0xdead_beef);
        let gate = ChaosGate::check(&plan, &faulted, &baseline);
        assert!(!gate.passed());
        assert!(!gate.digest_match);
        assert_eq!(gate.first_divergence, Some(key));
        let line = chaos_summary(&gate, &faulted);
        assert!(line.contains("FAIL"), "{line}");
        let (tenant, seq) = key;
        assert!(
            line.contains(&format!("first divergence: tenant {tenant} seq {seq}")),
            "{line}"
        );
        // The flight recorder recorded this tenant's run, so the dump
        // has at least one indented timeline line.
        assert!(line.lines().any(|l| l.starts_with("    ")), "{line}");
        // A request missing from one side entirely is also a divergence.
        faulted.output_digests.remove(&key);
        let missing = ChaosGate::check(&plan, &faulted, &baseline);
        assert_eq!(missing.first_divergence, Some(key));
        assert!(!missing.digest_match);
    }
}
