//! Observability tables: top-K hottest nodes, worst stall attributions,
//! the lattice-demotion ledger, and the single-source histogram bucket
//! table (rendered from [`Histogram::buckets`], the same rows the JSON
//! serializer uses, so labels can never drift).

use crate::obs::prof::EngineProfile;
use crate::obs::trace::{SpanKind, TraceEvent};
use crate::serve::Histogram;
use std::fmt::Write as _;

/// Top-`k` nodes by firing count for one engine profile.
pub fn hottest_nodes_table(label: &str, p: &EngineProfile, k: usize) -> String {
    let mut out = String::new();
    writeln!(out, "hottest nodes — {label} ({} total firings)", p.total_firings).unwrap();
    writeln!(out, "{:>6} {:>12} {:>10}", "node", "firings", "share%").unwrap();
    for (ni, s) in p.hottest_nodes(k) {
        if s.firings == 0 {
            break;
        }
        let share = 100.0 * s.firings as f64 / p.total_firings.max(1) as f64;
        writeln!(out, "{ni:>6} {:>12} {share:>9.1}%", s.firings).unwrap();
    }
    out
}

/// Top-`k` nodes by total stall count, split by attribution cause.
pub fn stall_table(label: &str, p: &EngineProfile, k: usize) -> String {
    let mut out = String::new();
    writeln!(out, "worst stall attributions — {label}").unwrap();
    writeln!(
        out,
        "{:>6} {:>10} {:>14} {:>15} {:>12}",
        "node", "stalls", "input-starved", "output-blocked", "gate-closed"
    )
    .unwrap();
    for (ni, s) in p.worst_stalls(k) {
        if s.stall_total() == 0 {
            break;
        }
        writeln!(
            out,
            "{ni:>6} {:>10} {:>14} {:>15} {:>12}",
            s.stall_total(),
            s.input_starved,
            s.output_blocked,
            s.gate_closed
        )
        .unwrap();
    }
    out
}

/// The lattice-demotion ledger: every Demote / Migrate / Retry / Evict
/// event in tick order — what the recovery path actually did.
pub fn demotion_ledger(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    out.push_str("lattice-demotion ledger\n");
    let mut any = false;
    for e in events {
        if !matches!(
            e.kind,
            SpanKind::Demote | SpanKind::Migrate | SpanKind::Retry | SpanKind::Evict
        ) {
            continue;
        }
        any = true;
        out.push_str(&format_event(e));
        out.push('\n');
    }
    if !any {
        out.push_str("  (no demotions, migrations, retries, or evictions)\n");
    }
    out
}

/// One event as a human-readable ledger/timeline line.
pub fn format_event(e: &TraceEvent) -> String {
    let tenant = if e.tenant == TraceEvent::NO_TENANT {
        "-".to_string()
    } else {
        e.tenant.to_string()
    };
    format!(
        "  [tick {:>6}] {:<12} tenant={tenant} seq={} engine={} cycles={} detail={}",
        e.tick,
        e.kind.name(),
        e.seq,
        e.engine,
        e.cycles,
        e.detail
    )
}

/// Latency-bucket table from [`Histogram::buckets`] — the same rows the
/// JSON export serializes, unit-tested to agree bound-for-bound.
pub fn histogram_table(label: &str, h: &Histogram) -> String {
    let mut out = String::new();
    writeln!(out, "latency buckets — {label} ({} samples)", h.count()).unwrap();
    if h.is_empty() {
        out.push_str("  (empty)\n");
        return out;
    }
    writeln!(out, "{:>20} {:>20} {:>10}", "lo_ns", "hi_ns", "count").unwrap();
    for (lo, hi, c) in h.buckets() {
        writeln!(out, "{lo:>20} {hi:>20} {c:>10}").unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::prof::{ProfileLevel, StallCause};

    fn profile() -> EngineProfile {
        let mut p = EngineProfile::new("token", ProfileLevel::Full, 4, 4);
        p.fire_n(2, 10);
        p.fire_n(0, 3);
        p.stall(1, StallCause::OutputBlocked);
        p.stall(1, StallCause::InputStarved);
        p.stall(3, StallCause::GateClosed);
        p
    }

    #[test]
    fn hottest_and_stall_tables_rank_deterministically() {
        let p = profile();
        let hot = hottest_nodes_table("tok", &p, 2);
        let first = hot.lines().nth(2).unwrap();
        assert!(first.trim_start().starts_with('2'), "{hot}");
        let stalls = stall_table("tok", &p, 4);
        let first = stalls.lines().nth(2).unwrap();
        assert!(first.trim_start().starts_with('1'), "{stalls}");
    }

    #[test]
    fn ledger_filters_recovery_events_only() {
        let mk = |kind| TraceEvent {
            kind,
            tenant: 1,
            seq: 9,
            tick: 5,
            cycles: 0,
            engine: "chaos",
            detail: 2,
        };
        let evs = [mk(SpanKind::Execute), mk(SpanKind::Demote), mk(SpanKind::Retry)];
        let ledger = demotion_ledger(&evs);
        assert!(ledger.contains("demote"));
        assert!(ledger.contains("retry"));
        assert!(!ledger.contains("execute"));
        let empty = demotion_ledger(&[mk(SpanKind::Execute)]);
        assert!(empty.contains("no demotions"));
    }

    #[test]
    fn histogram_table_rows_match_buckets_exactly() {
        let mut h = Histogram::new();
        for ns in [800u64, 1_200, 1_200, 40_000] {
            h.record(ns);
        }
        let table = histogram_table("global", &h);
        for (lo, hi, c) in h.buckets() {
            let row = format!("{lo:>20} {hi:>20} {c:>10}");
            assert!(table.contains(&row), "missing row {row:?} in:\n{table}");
        }
        // Exactly one table row per bucket row (plus 2 header lines).
        assert_eq!(table.lines().count(), 2 + h.buckets().len());
        assert!(histogram_table("empty", &Histogram::new()).contains("(empty)"));
    }
}
