//! The `opt` CLI subcommand's report: before/after structure and
//! resource estimates for every benchmark graph under the optimizer
//! pipeline, with built-in output-equivalence verification.
//!
//! Every row runs the raw and the optimized graph through `TokenSim`
//! on a deterministic workload and compares the streams on every
//! *named* output port (anonymous `sN` dangles are drain wires the
//! optimizer may remove; see DESIGN.md §9). Rows that fail
//! verification are flagged and the CLI refuses to write the
//! OPT_*.json trajectory — numbers from a wrong rewrite must never
//! land in an artifact.

use crate::bench_defs::{self, BenchId};
use crate::dfg::{is_anon_label, Graph, Word};
use crate::estimate::estimate;
use crate::opt::{optimize, OptLevel, OptReport};
use crate::sim::{run_token, SimConfig, SimOutcome};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One graph's trip through the pipeline.
#[derive(Debug)]
pub struct OptRow {
    pub name: String,
    /// `built` (the hand-crafted paper graph) or `lowered` (the mini-C
    /// frontend's raw output).
    pub source: &'static str,
    pub nodes_before: usize,
    pub nodes_after: usize,
    pub arcs_before: usize,
    pub arcs_after: usize,
    pub ff_before: u32,
    pub ff_after: u32,
    pub lut_before: u32,
    pub lut_after: u32,
    pub fmax_before: f64,
    pub fmax_after: f64,
    pub report: OptReport,
    /// Raw and optimized named-output streams were byte-identical and
    /// the optimized graph met the workload's reference expectations.
    pub verified: bool,
}

/// The streams on named output ports only — the optimizer's
/// equivalence surface.
pub fn named_outputs(out: &SimOutcome) -> BTreeMap<String, Vec<Word>> {
    out.outputs
        .iter()
        .filter(|(name, _)| !is_anon_label(name))
        .map(|(name, v)| (name.clone(), v.clone()))
        .collect()
}

fn verify(raw: &Graph, opt: &Graph, cfg: &SimConfig, expect: &BTreeMap<String, Vec<Word>>) -> bool {
    let raw_out = run_token(raw, cfg);
    let opt_out = run_token(opt, cfg);
    if named_outputs(&raw_out) != named_outputs(&opt_out) {
        return false;
    }
    expect
        .iter()
        .all(|(port, want)| opt_out.stream(port) == want.as_slice())
}

fn row(
    name: &str,
    source: &'static str,
    raw: Graph,
    level: OptLevel,
    cfg: &SimConfig,
    expect: &BTreeMap<String, Vec<Word>>,
) -> OptRow {
    let (og, report) = optimize(&raw, level);
    let (rb, ra) = (estimate(&raw), estimate(&og));
    OptRow {
        name: name.to_string(),
        source,
        nodes_before: raw.n_nodes(),
        nodes_after: og.n_nodes(),
        arcs_before: raw.n_arcs(),
        arcs_after: og.n_arcs(),
        ff_before: rb.ff,
        ff_after: ra.ff,
        lut_before: rb.lut,
        lut_after: ra.lut,
        fmax_before: rb.fmax_mhz,
        fmax_after: ra.fmax_mhz,
        verified: verify(&raw, &og, cfg, expect),
        report,
    }
}

/// Every benchmark graph — the six paper graphs plus SAXPY in their
/// hand-built form, and the six frontend-lowered (raw, unoptimized)
/// forms — through the pipeline at `level`.
pub fn opt_rows(level: OptLevel) -> Vec<OptRow> {
    let mut rows = Vec::new();
    for b in BenchId::ALL {
        let wl = bench_defs::workload(b, 6, 17);
        let cfg = wl.sim_config();
        rows.push(row(
            b.slug(),
            "built",
            bench_defs::build(b),
            level,
            &cfg,
            &wl.expect,
        ));
    }
    {
        let (inject, z) = bench_defs::saxpy::wave(6, 17);
        let mut cfg = SimConfig::new().max_cycles(200_000);
        for (p, s) in &inject {
            cfg = cfg.inject(p, s.clone());
        }
        let expect = BTreeMap::from([("z".to_string(), z)]);
        rows.push(row(
            "saxpy",
            "built",
            bench_defs::saxpy::build(),
            level,
            &cfg,
            &expect,
        ));
    }
    for b in BenchId::ALL {
        let raw = crate::frontend::compile_with(b.slug(), bench_defs::c_source(b), OptLevel::None)
            .expect("benchmark C source compiles");
        let wl = bench_defs::workload(b, 6, 17);
        let mut cfg = wl.sim_config();
        cfg.max_cycles *= 4;
        rows.push(row(b.slug(), "lowered", raw, level, &cfg, &wl.expect));
    }
    rows
}

/// Fixed-width table, one row per graph, estimate deltas included.
pub fn render_table(rows: &[OptRow], level: OptLevel) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "optimizer pipeline @ {level}");
    let _ = writeln!(
        out,
        "{:<14} {:<8} {:>11} {:>11} {:>13} {:>13} {:>13} {:>9}",
        "benchmark", "source", "nodes", "arcs", "FF", "LUT", "fmax MHz", "verified"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<14} {:<8} {:>4} -> {:<4} {:>4} -> {:<4} {:>5} -> {:<5} {:>5} -> {:<5} {:>5.1} -> {:<5.1} {:>7}",
            r.name,
            r.source,
            r.nodes_before,
            r.nodes_after,
            r.arcs_before,
            r.arcs_after,
            r.ff_before,
            r.ff_after,
            r.lut_before,
            r.lut_after,
            r.fmax_before,
            r.fmax_after,
            if r.verified { "yes" } else { "NO" },
        );
    }
    let reduced = rows
        .iter()
        .filter(|r| r.nodes_after < r.nodes_before || r.arcs_after < r.arcs_before)
        .count();
    let _ = writeln!(
        out,
        "{reduced}/{} graphs strictly reduced (nodes or arcs)",
        rows.len()
    );
    out
}

/// Hand-rolled JSON trajectory (schema `dataflow-accel-opt/v1`), the
/// artifact CI's `opt-smoke` job uploads.
pub fn to_json(rows: &[OptRow], level: OptLevel) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"dataflow-accel-opt/v1\",\n");
    let _ = writeln!(out, "  \"level\": \"{level}\",");
    let reduced = rows
        .iter()
        .filter(|r| r.nodes_after < r.nodes_before || r.arcs_after < r.arcs_before)
        .count();
    let _ = writeln!(out, "  \"graphs_reduced\": {reduced},");
    let _ = writeln!(out, "  \"graphs_total\": {},", rows.len());
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str("    {\n");
        let _ = writeln!(out, "      \"name\": \"{}\",", r.name);
        let _ = writeln!(out, "      \"source\": \"{}\",", r.source);
        let _ = writeln!(out, "      \"nodes_before\": {},", r.nodes_before);
        let _ = writeln!(out, "      \"nodes_after\": {},", r.nodes_after);
        let _ = writeln!(out, "      \"arcs_before\": {},", r.arcs_before);
        let _ = writeln!(out, "      \"arcs_after\": {},", r.arcs_after);
        let _ = writeln!(out, "      \"ff_before\": {},", r.ff_before);
        let _ = writeln!(out, "      \"ff_after\": {},", r.ff_after);
        let _ = writeln!(out, "      \"lut_before\": {},", r.lut_before);
        let _ = writeln!(out, "      \"lut_after\": {},", r.lut_after);
        let _ = writeln!(out, "      \"fmax_before\": {:.2},", r.fmax_before);
        let _ = writeln!(out, "      \"fmax_after\": {:.2},", r.fmax_after);
        let _ = writeln!(out, "      \"iterations\": {},", r.report.iterations);
        out.push_str("      \"passes\": [\n");
        for (j, p) in r.report.passes.iter().enumerate() {
            let _ = write!(
                out,
                "        {{\"pass\": \"{}\", \"applications\": {}, \"nodes_delta\": {}, \
                 \"arcs_delta\": {}, \"rewrites\": {}}}",
                p.name, p.applications, p.nodes_delta, p.arcs_delta, p.rewrites
            );
            out.push_str(if j + 1 < r.report.passes.len() { ",\n" } else { "\n" });
        }
        out.push_str("      ],\n");
        let _ = writeln!(out, "      \"verified\": {}", r.verified);
        out.push_str(if i + 1 < rows.len() { "    },\n" } else { "    }\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_verify_and_lowered_graphs_reduce() {
        let rows = opt_rows(OptLevel::Default);
        assert_eq!(rows.len(), 13);
        for r in &rows {
            assert!(r.verified, "{} ({}) failed verification", r.name, r.source);
            assert!(
                r.nodes_after <= r.nodes_before && r.arcs_after <= r.arcs_before,
                "{} ({}) grew",
                r.name,
                r.source
            );
        }
        // Acceptance: every frontend-lowered graph strictly shrinks.
        for r in rows.iter().filter(|r| r.source == "lowered") {
            assert!(
                r.nodes_after < r.nodes_before,
                "{} (lowered) did not shrink",
                r.name
            );
        }
    }

    #[test]
    fn table_and_json_render() {
        // One benchmark's worth keeps the test fast.
        let wl = crate::bench_defs::workload(BenchId::Fibonacci, 5, 3);
        let rows = vec![super::row(
            "fibonacci",
            "built",
            crate::bench_defs::build(BenchId::Fibonacci),
            OptLevel::Default,
            &wl.sim_config(),
            &wl.expect,
        )];
        let table = render_table(&rows, OptLevel::Default);
        assert!(table.contains("fibonacci"), "{table}");
        let json = to_json(&rows, OptLevel::Default);
        assert!(json.contains("\"schema\": \"dataflow-accel-opt/v1\""));
        assert!(json.contains("\"verified\": true"));
    }
}
