//! Table 1 / Fig. 8 renderers, plus the perf harness ([`perf`]).
//!
//! [`table1`] regenerates the paper's Table 1 — FF / LUT / Slices / Max
//! Freq for every benchmark under C-to-Verilog, LALP and the Algorithm
//! Accelerator — side by side with the paper's published numbers.
//! [`fig8_csv`] emits the same data as the four bar-chart series of
//! Fig. 8 in CSV form (one panel per metric). [`perf`] is the `bench`
//! subcommand's engine-comparison harness (scalar vs streamed vs lane
//! engines, BENCH_*.json trajectory). [`serve`] renders the service
//! tier's per-tenant summary ([`serve::serve_table`]) and the
//! SERVE_*.json trajectory. [`chaos`] renders the fault-injection
//! gate's verdict (CHAOS_*.json, written only when the
//! zero-lost-requests gate passes). [`elastic`] renders the
//! rolling-repartition gate's verdict (ELASTIC_*.json, written only
//! when the elastic run promotes a tenant with zero lost requests and
//! baseline-identical digests). [`obs`] renders the observability
//! tables: hottest nodes, worst stall attributions, the
//! lattice-demotion ledger, and the single-source latency-bucket table.

pub mod chaos;
pub mod elastic;
pub mod obs;
pub mod opt;
pub mod perf;
pub mod serve;

pub use chaos::{chaos_summary, ChaosGate};
pub use elastic::{elastic_summary, ElasticGate};
pub use obs::{demotion_ledger, histogram_table, hottest_nodes_table, stall_table};
pub use serve::{scaling_table, serve_table, ScalePoint};

use crate::baselines::{ctv, kernel_spec, lalp};
use crate::bench_defs::{self, build, BenchId};
use crate::dfg::Graph;
use crate::estimate::{estimate, estimate_shards, estimate_trimmed, Resources};
use crate::fabric::{self, FabricTopology};
use crate::sim::{self, run_token, WaveInput, WaveMode};
use std::fmt::Write;

/// The paper's published Table 1 numbers (FF, LUT, Slices, Fmax MHz).
/// `None` where the paper's table has no entry.
pub fn paper_row(system: System, b: BenchId) -> Option<(u32, u32, u32, f64)> {
    use BenchId::*;
    match system {
        System::CToVerilog => Some(match b {
            BubbleSort => (2353, 2471, 971, 239.45),
            DotProd => (758, 578, 285, 249.36),
            Fibonacci => (73, 108, 69, 297.81),
            Max => (496, 392, 164, 435.9),
            PopCount => (1023, 872, 384, 411.22),
            VectorSum => (177, 113, 34, 546.538),
        }),
        System::Lalp => match b {
            BubbleSort => Some((219, 105, 79, 353.16)),
            DotProd => Some((97, 69, 32, 213.14)),
            Fibonacci => Some((104, 41, 30, 505.08)),
            Max => Some((50, 39, 20, 484.97)),
            PopCount => None, // no LALP entry in the paper's table
            VectorSum => Some((350, 215, 115, 503.73)),
        },
        System::Ours => Some(match b {
            BubbleSort => (85, 485, 712, 613.685),
            DotProd => (323, 362, 542, 613.685),
            Fibonacci => (72, 482, 755, 612.108),
            Max => (80, 425, 598, 613.685),
            PopCount => (79, 453, 684, 613.685),
            VectorSum => (52, 284, 419, 613.685),
        }),
    }
}

/// The three systems of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    CToVerilog,
    Lalp,
    Ours,
}

impl System {
    pub const ALL: [System; 3] = [System::CToVerilog, System::Lalp, System::Ours];

    pub fn paper_name(self) -> &'static str {
        match self {
            System::CToVerilog => "C-to-Verilog",
            System::Lalp => "LALP",
            System::Ours => "Algorithm Accelerator",
        }
    }
}

/// Our measured/estimated resources for (system, benchmark).
/// For `Ours` the control-trimmed FF model is used for the FF column
/// (matching what the paper's synthesis evidently measured — see
/// `estimate` module docs) and the full model for LUT/slices/Fmax.
pub fn measured_row(system: System, b: BenchId) -> Option<Resources> {
    match system {
        System::CToVerilog => Some(ctv::estimate(&kernel_spec(b))),
        System::Lalp => lalp::estimate(&kernel_spec(b)),
        System::Ours => {
            let g = build(b);
            let full = estimate(&g);
            let trimmed = estimate_trimmed(&g);
            Some(Resources {
                ff: trimmed.ff,
                ..full
            })
        }
    }
}

/// Render the full Table 1 comparison (paper vs measured).
pub fn table1() -> String {
    let mut out = String::new();
    writeln!(
        out,
        "Table 1: resources per benchmark and system (paper → measured)"
    )
    .unwrap();
    writeln!(
        out,
        "{:<22} {:<12} {:>14} {:>14} {:>14} {:>20}",
        "System", "Benchmark", "FF", "LUT", "Slices", "Max Freq (MHz)"
    )
    .unwrap();
    let dash = "-".repeat(100);
    for sys in System::ALL {
        writeln!(out, "{dash}").unwrap();
        for b in BenchId::ALL {
            let paper = paper_row(sys, b);
            let meas = measured_row(sys, b);
            match (paper, meas) {
                (Some(p), Some(m)) => writeln!(
                    out,
                    "{:<22} {:<12} {:>6} → {:<6} {:>6} → {:<6} {:>6} → {:<6} {:>8.1} → {:<8.1}",
                    sys.paper_name(),
                    b.paper_name(),
                    p.0,
                    m.ff,
                    p.1,
                    m.lut,
                    p.2,
                    m.slices,
                    p.3,
                    m.fmax_mhz
                )
                .unwrap(),
                (None, None) => writeln!(
                    out,
                    "{:<22} {:<12} {:>14} {:>14} {:>14} {:>20}",
                    sys.paper_name(),
                    b.paper_name(),
                    "—",
                    "—",
                    "—",
                    "—"
                )
                .unwrap(),
                _ => unreachable!("paper and model agree on missing rows"),
            }
        }
    }
    out
}

/// Fig. 8 as CSV: `metric,benchmark,c_to_verilog,lalp,ours` (one block
/// per panel: ff, lut, slices, fmax). Empty cell where the paper has no
/// entry.
pub fn fig8_csv() -> String {
    let mut out = String::new();
    for (metric, get) in [
        ("ff", 0usize),
        ("lut", 1),
        ("slices", 2),
        ("fmax_mhz", 3),
    ] {
        writeln!(out, "metric,benchmark,c_to_verilog,lalp,ours").unwrap();
        for b in BenchId::ALL {
            let cell = |sys: System| -> String {
                measured_row(sys, b)
                    .map(|r| match get {
                        0 => r.ff.to_string(),
                        1 => r.lut.to_string(),
                        2 => r.slices.to_string(),
                        _ => format!("{:.1}", r.fmax_mhz),
                    })
                    .unwrap_or_default()
            };
            writeln!(
                out,
                "{metric},{},{},{},{}",
                b.slug(),
                cell(System::CToVerilog),
                cell(System::Lalp),
                cell(System::Ours)
            )
            .unwrap();
        }
    }
    out
}

/// Placement / utilization report for one graph on one fabric topology.
///
/// A graph that fits prints the per-class slot utilization and channel
/// occupancy of its placement. A graph that does not fit prints the
/// placer's rejection, then the partition: one row per shard with node /
/// arc / cut counts and the per-shard FF/LUT/slice estimate.
pub fn placement_table(g: &Graph, topo: &FabricTopology) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "Placement: `{}` ({} nodes, {} arcs) on fabric `{}` \
         ({} slots, {} channels, reconfig {} cy)",
        g.name,
        g.n_nodes(),
        g.n_arcs(),
        topo.name,
        topo.total_slots(),
        topo.channels,
        topo.reconfig_cycles
    )
    .unwrap();
    match fabric::place(g, topo) {
        Ok(p) => {
            writeln!(out, "{:<10} {:>6} {:>6} {:>6}", "class", "used", "total", "util").unwrap();
            for (class, used, total) in p.utilization(topo) {
                let pct = if total > 0 {
                    100.0 * used as f64 / total as f64
                } else {
                    0.0
                };
                writeln!(
                    out,
                    "{:<10} {:>6} {:>6} {:>5.0}%",
                    class.name(),
                    used,
                    total,
                    pct
                )
                .unwrap();
            }
            let (cu, ct) = p.channel_utilization(topo);
            writeln!(
                out,
                "{:<10} {:>6} {:>6} {:>5.0}%",
                "channels",
                cu,
                ct,
                100.0 * cu as f64 / ct.max(1) as f64
            )
            .unwrap();
        }
        Err(e) => {
            writeln!(out, "does not fit one instance: {e}").unwrap();
            match fabric::partition(g, topo) {
                Ok(plan) => {
                    let (per, total) =
                        estimate_shards(plan.shards.iter().map(|s| &s.graph));
                    writeln!(
                        out,
                        "partitioned into {} shards, {} cut arcs",
                        plan.n_shards(),
                        plan.cuts.len()
                    )
                    .unwrap();
                    writeln!(
                        out,
                        "{:<8} {:>6} {:>6} {:>6} {:>8} {:>8} {:>8}",
                        "shard", "nodes", "arcs", "cuts", "FF", "LUT", "slices"
                    )
                    .unwrap();
                    for (sh, r) in plan.shards.iter().zip(&per) {
                        let cuts = plan
                            .cuts
                            .iter()
                            .filter(|c| c.from == sh.index || c.to == sh.index)
                            .count();
                        writeln!(
                            out,
                            "{:<8} {:>6} {:>6} {:>6} {:>8} {:>8} {:>8}",
                            sh.index,
                            sh.graph.n_nodes(),
                            sh.graph.n_arcs(),
                            cuts,
                            r.ff,
                            r.lut,
                            r.slices
                        )
                        .unwrap();
                    }
                    writeln!(
                        out,
                        "{:<8} {:>6} {:>6} {:>6} {:>8} {:>8} {:>8}",
                        "total",
                        g.n_nodes(),
                        g.n_arcs(),
                        plan.cuts.len(),
                        total.ff,
                        total.lut,
                        total.slices
                    )
                    .unwrap();
                }
                Err(e) => writeln!(out, "unpartitionable on this fabric: {e}").unwrap(),
            }
        }
    }
    out
}

/// One row of the streaming throughput comparison.
#[derive(Debug, Clone)]
pub struct ThroughputRow {
    pub name: String,
    pub mode: WaveMode,
    pub waves: usize,
    pub tokens_out: u64,
    /// Total rounds running every wave to completion separately.
    pub r2c_cycles: u64,
    /// Makespan of the same waves through one resident session.
    pub streamed_cycles: u64,
}

impl ThroughputRow {
    pub fn r2c_tokens_per_cycle(&self) -> f64 {
        self.tokens_out as f64 / self.r2c_cycles.max(1) as f64
    }
    pub fn streamed_tokens_per_cycle(&self) -> f64 {
        self.tokens_out as f64 / self.streamed_cycles.max(1) as f64
    }
    pub fn speedup(&self) -> f64 {
        self.r2c_cycles as f64 / self.streamed_cycles.max(1) as f64
    }
}

/// Measure one graph: run `waves` to completion one at a time, then
/// pipeline the identical waves through a resident [`sim::StreamSession`].
pub fn throughput_row(name: &str, g: &Graph, waves: &[WaveInput], budget: u64) -> ThroughputRow {
    let mut r2c_cycles = 0u64;
    let mut tokens_out = 0u64;
    for wave in waves {
        let mut cfg = crate::sim::SimConfig::new().max_cycles(budget);
        for (p, s) in wave {
            cfg = cfg.inject(p, s.clone());
        }
        let out = run_token(g, &cfg);
        r2c_cycles += out.cycles;
        tokens_out += out.outputs.values().map(|v| v.len() as u64).sum::<u64>();
    }
    let (_, metrics) = sim::run_stream(g, waves, budget * waves.len().max(1) as u64);
    ThroughputRow {
        name: name.to_string(),
        // The admission policy actually used (run_stream serializes a
        // pipelined-capable graph when the waves fail unit-rate
        // admission, e.g. unequal per-port stream lengths).
        mode: metrics.mode,
        waves: waves.len(),
        tokens_out,
        r2c_cycles,
        streamed_cycles: metrics.rounds,
    }
}

/// The streamed-vs-run-to-completion rows for the whole suite: the six
/// paper benchmarks (serialized waves over a resident session) plus the
/// pipelineable SAXPY workload (overlapped waves — the Fig. 1c case).
pub fn throughput_rows(waves: usize, n: usize, seed: u64) -> Vec<ThroughputRow> {
    let mut rows = Vec::new();
    for b in BenchId::ALL {
        let g = build(b);
        let wls = bench_defs::wave_workloads(b, waves, n, seed);
        let budget = wls.iter().map(|w| w.max_cycles).max().unwrap_or(1_000_000);
        let ws: Vec<WaveInput> = wls.iter().map(|w| w.inject.clone()).collect();
        rows.push(throughput_row(b.slug(), &g, &ws, budget));
    }
    let g = bench_defs::saxpy::build();
    let ws: Vec<WaveInput> = (0..waves)
        .map(|i| bench_defs::saxpy::wave(n, seed.wrapping_add(i as u64)).0)
        .collect();
    rows.push(throughput_row("saxpy", &g, &ws, 1_000_000));
    rows
}

/// Fig. 8-style sustained-throughput table: tokens/cycle run-to-
/// completion vs streamed, per benchmark.
pub fn throughput_table(waves: usize, n: usize, seed: u64) -> String {
    let rows = throughput_rows(waves, n, seed);
    let mut out = String::new();
    writeln!(
        out,
        "Sustained throughput: {waves} waves of size {n} per benchmark \
         (run-to-completion vs streamed session)"
    )
    .unwrap();
    writeln!(
        out,
        "{:<12} {:>10} {:>7} {:>8} {:>10} {:>10} {:>10} {:>10} {:>8}",
        "benchmark",
        "admission",
        "waves",
        "tokens",
        "r2c cyc",
        "strm cyc",
        "r2c tok/c",
        "strm tok/c",
        "speedup"
    )
    .unwrap();
    for r in &rows {
        writeln!(
            out,
            "{:<12} {:>10} {:>7} {:>8} {:>10} {:>10} {:>10.4} {:>10.4} {:>7.2}x",
            r.name,
            match r.mode {
                WaveMode::Pipelined => "pipelined",
                WaveMode::Serialized => "serialized",
            },
            r.waves,
            r.tokens_out,
            r.r2c_cycles,
            r.streamed_cycles,
            r.r2c_tokens_per_cycle(),
            r.streamed_tokens_per_cycle(),
            r.speedup()
        )
        .unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_all_rows() {
        let t = table1();
        for b in BenchId::ALL {
            assert!(t.contains(b.paper_name()), "missing {}", b.paper_name());
        }
        for s in System::ALL {
            assert!(t.contains(s.paper_name()));
        }
        // 3 systems × 6 benchmarks + headers/rules.
        assert!(t.lines().count() >= 18);
    }

    #[test]
    fn fig8_csv_has_four_panels() {
        let csv = fig8_csv();
        assert_eq!(
            csv.matches("metric,benchmark").count(),
            4,
            "one header per panel"
        );
        assert_eq!(csv.matches("fmax_mhz,").count(), 6);
        // LALP pop_count cell is empty.
        assert!(csv.contains("ff,pop_count,") && csv.contains(",,"));
    }

    #[test]
    fn placement_table_renders_fit_and_split() {
        let g = build(BenchId::Max);
        let topo = FabricTopology::paper();
        let t = placement_table(&g, &topo);
        assert!(t.contains("class"), "{t}");
        assert!(t.contains("channels"), "{t}");

        let half = FabricTopology::sized_for_shards(&g, 2);
        let t2 = placement_table(&g, &half);
        assert!(t2.contains("does not fit one instance"), "{t2}");
        assert!(t2.contains("partitioned into"), "{t2}");
        assert!(t2.contains("shard"), "{t2}");
    }

    #[test]
    fn throughput_table_covers_suite_and_pipelines_win() {
        let rows = throughput_rows(4, 3, 11);
        assert_eq!(rows.len(), BenchId::ALL.len() + 1);
        let t = throughput_table(4, 3, 11);
        for b in BenchId::ALL {
            assert!(t.contains(b.slug()), "missing {}", b.slug());
        }
        assert!(t.contains("saxpy"));
        for r in &rows {
            assert!(r.tokens_out > 0, "{}: no output tokens", r.name);
            if r.mode == WaveMode::Pipelined {
                assert!(
                    r.streamed_tokens_per_cycle() >= r.r2c_tokens_per_cycle(),
                    "{}: streamed {} < r2c {} tokens/cycle",
                    r.name,
                    r.streamed_tokens_per_cycle(),
                    r.r2c_tokens_per_cycle()
                );
            }
        }
        // The canonical pipeline must actually show the Fig. 1c win.
        let saxpy = rows.iter().find(|r| r.name == "saxpy").unwrap();
        assert_eq!(saxpy.mode, WaveMode::Pipelined);
        assert!(saxpy.speedup() > 1.0, "saxpy speedup {}", saxpy.speedup());
    }

    #[test]
    fn paper_numbers_are_transcribed_consistently() {
        // Spot-check a few cells against the paper text.
        assert_eq!(
            paper_row(System::Ours, BenchId::VectorSum),
            Some((52, 284, 419, 613.685))
        );
        assert_eq!(
            paper_row(System::CToVerilog, BenchId::BubbleSort),
            Some((2353, 2471, 971, 239.45))
        );
        assert_eq!(paper_row(System::Lalp, BenchId::PopCount), None);
    }
}
