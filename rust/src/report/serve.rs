//! Renderers for the service tier: the per-tenant summary table the
//! `serve` CLI prints, and the hand-rolled `SERVE_<k>.json` trajectory
//! (schema `dataflow-accel-serve/v1`) the CI smoke job validates and
//! archives. No JSON dependency — same approach as [`super::perf`].

use crate::serve::{ServeReport, TenantStats};
use std::fmt::Write as _;

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

fn tenant_row(out: &mut String, t: &TenantStats) {
    writeln!(
        out,
        "{:<12} {:>9} {:>9} {:>6} {:>9} {:>8} {:>9.3} {:>9.3} {:>9.3} {:>9.1}",
        t.name,
        t.submitted,
        t.completed,
        t.shed(),
        t.verified,
        t.batches,
        ms(t.latency.p50_ns()),
        ms(t.latency.p95_ns()),
        ms(t.latency.p99_ns()),
        t.mean_wait_ticks(),
    )
    .unwrap();
}

/// The per-tenant summary table (stdout of the `serve` subcommand).
pub fn serve_table(r: &ServeReport) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "Service tier: {} tenant(s), {} tick(s), max queue depth {}",
        r.tenants.len(),
        r.ticks,
        r.max_queue_depth
    )
    .unwrap();
    writeln!(
        out,
        "{:<12} {:>9} {:>9} {:>6} {:>9} {:>8} {:>9} {:>9} {:>9} {:>9}",
        "tenant",
        "submitted",
        "completed",
        "shed",
        "verified",
        "batches",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "wait tk"
    )
    .unwrap();
    for t in &r.tenants {
        tenant_row(&mut out, t);
    }
    tenant_row(&mut out, &r.global);
    let engines: Vec<String> = r
        .global
        .engine_requests
        .iter()
        .map(|(e, n)| format!("{e} {n}"))
        .collect();
    writeln!(
        out,
        "engines: {} | lane scalar reruns {}",
        if engines.is_empty() {
            "none".to_string()
        } else {
            engines.join(", ")
        },
        r.lane_scalar_reruns
    )
    .unwrap();
    writeln!(
        out,
        "cache: {} hit(s), {} miss(es), {} eviction(s) | lost requests {}",
        r.cache_hits,
        r.cache_misses,
        r.cache_evictions,
        r.global.lost()
    )
    .unwrap();
    out
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

fn stats_json(out: &mut String, indent: &str, t: &TenantStats) {
    writeln!(out, "{indent}\"name\": \"{}\",", json_escape(&t.name)).unwrap();
    writeln!(out, "{indent}\"submitted\": {},", t.submitted).unwrap();
    writeln!(out, "{indent}\"completed\": {},", t.completed).unwrap();
    writeln!(out, "{indent}\"shed\": {},", t.shed()).unwrap();
    writeln!(out, "{indent}\"shed_queue_full\": {},", t.shed_queue_full).unwrap();
    writeln!(out, "{indent}\"shed_quota\": {},", t.shed_quota).unwrap();
    writeln!(out, "{indent}\"lost\": {},", t.lost()).unwrap();
    writeln!(out, "{indent}\"verified\": {},", t.verified).unwrap();
    writeln!(out, "{indent}\"batches\": {},", t.batches).unwrap();
    writeln!(out, "{indent}\"fabric_cycles\": {},", t.fabric_cycles).unwrap();
    writeln!(out, "{indent}\"mean_wait_ticks\": {:.2},", t.mean_wait_ticks()).unwrap();
    let engines: Vec<String> = t
        .engine_requests
        .iter()
        .map(|(e, n)| format!("\"{e}\": {n}"))
        .collect();
    writeln!(out, "{indent}\"engine_requests\": {{{}}},", engines.join(", ")).unwrap();
    writeln!(out, "{indent}\"latency\": {{").unwrap();
    writeln!(out, "{indent}  \"count\": {},", t.latency.count()).unwrap();
    writeln!(out, "{indent}  \"mean_ns\": {},", t.latency.mean_ns()).unwrap();
    writeln!(out, "{indent}  \"min_ns\": {},", t.latency.min_ns()).unwrap();
    writeln!(out, "{indent}  \"max_ns\": {},", t.latency.max_ns()).unwrap();
    writeln!(out, "{indent}  \"p50_ns\": {},", t.latency.p50_ns()).unwrap();
    writeln!(out, "{indent}  \"p95_ns\": {},", t.latency.p95_ns()).unwrap();
    writeln!(out, "{indent}  \"p99_ns\": {}", t.latency.p99_ns()).unwrap();
    writeln!(out, "{indent}}}").unwrap();
}

/// Serialize a profile run (schema `dataflow-accel-serve/v1`). The
/// caller echoes its profile parameters so reruns are reproducible.
pub fn to_json(r: &ServeReport, seed: u64, scale: usize, n: usize, quick: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"dataflow-accel-serve/v1\",\n");
    writeln!(out, "  \"seed\": {seed},").unwrap();
    writeln!(out, "  \"scale\": {scale},").unwrap();
    writeln!(out, "  \"n\": {n},").unwrap();
    writeln!(out, "  \"quick\": {quick},").unwrap();
    writeln!(out, "  \"ticks\": {},", r.ticks).unwrap();
    writeln!(out, "  \"max_queue_depth\": {},", r.max_queue_depth).unwrap();
    writeln!(out, "  \"cache_hits\": {},", r.cache_hits).unwrap();
    writeln!(out, "  \"cache_misses\": {},", r.cache_misses).unwrap();
    writeln!(out, "  \"cache_evictions\": {},", r.cache_evictions).unwrap();
    writeln!(out, "  \"lane_scalar_reruns\": {},", r.lane_scalar_reruns).unwrap();
    out.push_str("  \"global\": {\n");
    stats_json(&mut out, "    ", &r.global);
    out.push_str("  },\n");
    out.push_str("  \"tenants\": [\n");
    for (i, t) in r.tenants.iter().enumerate() {
        let comma = if i + 1 < r.tenants.len() { "," } else { "" };
        out.push_str("    {\n");
        stats_json(&mut out, "      ", t);
        writeln!(out, "    }}{comma}").unwrap();
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{run_profile, standard_profile, ServeOptions};

    fn tiny_report() -> ServeReport {
        let profile = standard_profile(2, 3, 11);
        run_profile(&profile, &ServeOptions::default()).report
    }

    #[test]
    fn table_names_every_tenant_and_the_invariants() {
        let r = tiny_report();
        let t = serve_table(&r);
        for tenant in &r.tenants {
            assert!(t.contains(&tenant.name), "missing {}", tenant.name);
        }
        assert!(t.contains("global"));
        assert!(t.contains("p99 ms"));
        assert!(t.contains("lost requests 0"), "{t}");
    }

    #[test]
    fn json_is_structurally_sound_and_carries_the_schema() {
        let r = tiny_report();
        let json = to_json(&r, 11, 2, 3, true);
        assert!(json.starts_with("{\n") && json.ends_with("}\n"));
        assert!(json.contains("\"schema\": \"dataflow-accel-serve/v1\""));
        for field in ["\"p50_ns\"", "\"p95_ns\"", "\"p99_ns\""] {
            assert!(
                json.matches(field).count() >= r.tenants.len() + 1,
                "{field} missing"
            );
        }
        assert!(json.contains("\"lost\": 0"));
        assert!(json.contains("\"cache_hits\""));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert!(!json.contains("NaN") && !json.contains("inf"));
    }
}
