//! Renderers for the service tier: the per-tenant summary table the
//! `serve` CLI prints, and the hand-rolled `SERVE_<k>.json` trajectory
//! (schema `dataflow-accel-serve/v3`) the CI smoke job validates and
//! archives. No JSON dependency — same approach as [`super::perf`].
//!
//! v2 added the parallel-dispatch fields (`workers`, `wall_ns`,
//! `busy_ns`, `steals`, `tokens_out`, derived throughput/utilization)
//! and a `scaling` array — one [`ScalePoint`] per worker count from
//! the `serve --scale-workers` sweep, written only after every point's
//! result digests were verified byte-identical to the 1-worker run.
//!
//! v3 adds an explicit `"empty"` marker to every latency block (a
//! zero-request tenant reports `0` for every quantile, and the marker
//! keeps that distinguishable from genuine sub-microsecond latency)
//! and an optional `"chaos"` object with the fault-injection counters
//! of a `serve --chaos` run (`null` on fault-free runs).

use crate::serve::{ServeReport, TenantStats};
use std::fmt::Write as _;

/// One point on the worker-scaling curve: the same profile (same
/// seed, same trace, verified-identical results) at one worker count.
#[derive(Debug, Clone, Copy)]
pub struct ScalePoint {
    pub workers: usize,
    pub wall_ns: u64,
    pub busy_ns: u64,
    pub tokens_out: u64,
    pub completed: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
}

impl ScalePoint {
    pub fn from_report(r: &ServeReport) -> Self {
        ScalePoint {
            workers: r.workers,
            wall_ns: r.wall_ns,
            busy_ns: r.busy_ns,
            tokens_out: r.tokens_out,
            completed: r.global.completed,
            p50_ns: r.global.latency.p50_ns(),
            p95_ns: r.global.latency.p95_ns(),
            p99_ns: r.global.latency.p99_ns(),
        }
    }

    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.tokens_out as f64 / (self.wall_ns as f64 * 1e-9)
        }
    }
}

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

fn tenant_row(out: &mut String, t: &TenantStats) {
    // A tenant that completed nothing has no latency distribution;
    // dashes, not "0.000 ms", so the row can't be read as "very fast".
    let q = |ns: u64| {
        if t.latency.is_empty() {
            "-".to_string()
        } else {
            format!("{:.3}", ms(ns))
        }
    };
    writeln!(
        out,
        "{:<12} {:>9} {:>9} {:>6} {:>9} {:>8} {:>9} {:>9} {:>9} {:>9.1}",
        t.name,
        t.submitted,
        t.completed,
        t.shed(),
        t.verified,
        t.batches,
        q(t.latency.p50_ns()),
        q(t.latency.p95_ns()),
        q(t.latency.p99_ns()),
        t.mean_wait_ticks(),
    )
    .unwrap();
}

/// The per-tenant summary table (stdout of the `serve` subcommand).
pub fn serve_table(r: &ServeReport) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "Service tier: {} tenant(s), {} tick(s), max queue depth {}",
        r.tenants.len(),
        r.ticks,
        r.max_queue_depth
    )
    .unwrap();
    writeln!(
        out,
        "{:<12} {:>9} {:>9} {:>6} {:>9} {:>8} {:>9} {:>9} {:>9} {:>9}",
        "tenant",
        "submitted",
        "completed",
        "shed",
        "verified",
        "batches",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "wait tk"
    )
    .unwrap();
    for t in &r.tenants {
        tenant_row(&mut out, t);
    }
    tenant_row(&mut out, &r.global);
    let engines: Vec<String> = r
        .global
        .engine_requests
        .iter()
        .map(|(e, n)| format!("{e} {n}"))
        .collect();
    writeln!(
        out,
        "engines: {} | lane scalar reruns {}",
        if engines.is_empty() {
            "none".to_string()
        } else {
            engines.join(", ")
        },
        r.lane_scalar_reruns
    )
    .unwrap();
    writeln!(
        out,
        "cache: {} hit(s), {} miss(es), {} eviction(s) | lost requests {}",
        r.cache_hits,
        r.cache_misses,
        r.cache_evictions,
        r.global.lost()
    )
    .unwrap();
    writeln!(
        out,
        "dispatch: {} worker(s), wall {:.3} ms, busy {:.3} ms, {} steal(s) | \
         {} token(s) out, {:.0} tokens/s, util {:.2}",
        r.workers,
        ms(r.wall_ns),
        ms(r.busy_ns),
        r.steals,
        r.tokens_out,
        r.tokens_per_sec(),
        r.utilization()
    )
    .unwrap();
    if let Some(c) = &r.chaos {
        writeln!(
            out,
            "chaos: {} fault(s) (slot {}, bus {}, outage {}), {} repair(s) | \
             {} migration(s), {} wave(s) rescued, {} retry probe(s), \
             {} demotion(s), {} route purge(s)",
            c.faults_injected(),
            c.slot_faults,
            c.bus_faults,
            c.outages,
            c.repairs,
            c.migrations,
            c.rescued_waves,
            c.retries,
            c.demotions,
            c.route_invalidations
        )
        .unwrap();
    }
    out
}

/// The worker-scaling curve table (stdout of `serve --scale-workers`).
pub fn scaling_table(points: &[ScalePoint]) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "{:>7} {:>12} {:>12} {:>12} {:>10} {:>9} {:>9} {:>9} {:>8}",
        "workers",
        "wall ms",
        "busy ms",
        "tokens/s",
        "completed",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "speedup"
    )
    .unwrap();
    let base = points.first().map(|p| p.wall_ns).unwrap_or(0);
    for p in points {
        writeln!(
            out,
            "{:>7} {:>12.3} {:>12.3} {:>12.0} {:>10} {:>9.3} {:>9.3} {:>9.3} {:>7.2}x",
            p.workers,
            ms(p.wall_ns),
            ms(p.busy_ns),
            p.tokens_per_sec(),
            p.completed,
            ms(p.p50_ns),
            ms(p.p95_ns),
            ms(p.p99_ns),
            base as f64 / p.wall_ns.max(1) as f64
        )
        .unwrap();
    }
    out
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

fn stats_json(out: &mut String, indent: &str, t: &TenantStats) {
    writeln!(out, "{indent}\"name\": \"{}\",", json_escape(&t.name)).unwrap();
    writeln!(out, "{indent}\"submitted\": {},", t.submitted).unwrap();
    writeln!(out, "{indent}\"completed\": {},", t.completed).unwrap();
    writeln!(out, "{indent}\"shed\": {},", t.shed()).unwrap();
    writeln!(out, "{indent}\"shed_queue_full\": {},", t.shed_queue_full).unwrap();
    writeln!(out, "{indent}\"shed_quota\": {},", t.shed_quota).unwrap();
    writeln!(out, "{indent}\"lost\": {},", t.lost()).unwrap();
    writeln!(out, "{indent}\"verified\": {},", t.verified).unwrap();
    writeln!(out, "{indent}\"batches\": {},", t.batches).unwrap();
    writeln!(out, "{indent}\"fabric_cycles\": {},", t.fabric_cycles).unwrap();
    writeln!(out, "{indent}\"mean_wait_ticks\": {:.2},", t.mean_wait_ticks()).unwrap();
    let engines: Vec<String> = t
        .engine_requests
        .iter()
        .map(|(e, n)| format!("\"{e}\": {n}"))
        .collect();
    writeln!(out, "{indent}\"engine_requests\": {{{}}},", engines.join(", ")).unwrap();
    writeln!(out, "{indent}\"latency\": {{").unwrap();
    writeln!(out, "{indent}  \"empty\": {},", t.latency.is_empty()).unwrap();
    writeln!(out, "{indent}  \"count\": {},", t.latency.count()).unwrap();
    writeln!(out, "{indent}  \"mean_ns\": {},", t.latency.mean_ns()).unwrap();
    writeln!(out, "{indent}  \"min_ns\": {},", t.latency.min_ns()).unwrap();
    writeln!(out, "{indent}  \"max_ns\": {},", t.latency.max_ns()).unwrap();
    writeln!(out, "{indent}  \"p50_ns\": {},", t.latency.p50_ns()).unwrap();
    writeln!(out, "{indent}  \"p95_ns\": {},", t.latency.p95_ns()).unwrap();
    writeln!(out, "{indent}  \"p99_ns\": {},", t.latency.p99_ns()).unwrap();
    // Bucket rows come from Histogram::buckets() — the same single
    // source the table renderer (report::obs::histogram_table) reads,
    // so JSON bounds and table labels cannot drift.
    let buckets: Vec<String> = t
        .latency
        .buckets()
        .iter()
        .map(|(lo, hi, c)| format!("[{lo}, {hi}, {c}]"))
        .collect();
    writeln!(out, "{indent}  \"buckets\": [{}]", buckets.join(", ")).unwrap();
    writeln!(out, "{indent}}}").unwrap();
}

/// Serialize a profile run (schema `dataflow-accel-serve/v3`). The
/// caller echoes its profile parameters so reruns are reproducible;
/// `scaling` is the `--scale-workers` sweep (empty for a single run).
pub fn to_json(
    r: &ServeReport,
    seed: u64,
    scale: usize,
    n: usize,
    quick: bool,
    scaling: &[ScalePoint],
) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"dataflow-accel-serve/v3\",\n");
    writeln!(out, "  \"seed\": {seed},").unwrap();
    writeln!(out, "  \"scale\": {scale},").unwrap();
    writeln!(out, "  \"n\": {n},").unwrap();
    writeln!(out, "  \"quick\": {quick},").unwrap();
    writeln!(out, "  \"ticks\": {},", r.ticks).unwrap();
    writeln!(out, "  \"max_queue_depth\": {},", r.max_queue_depth).unwrap();
    writeln!(out, "  \"cache_hits\": {},", r.cache_hits).unwrap();
    writeln!(out, "  \"cache_misses\": {},", r.cache_misses).unwrap();
    writeln!(out, "  \"cache_evictions\": {},", r.cache_evictions).unwrap();
    writeln!(out, "  \"lane_scalar_reruns\": {},", r.lane_scalar_reruns).unwrap();
    writeln!(out, "  \"workers\": {},", r.workers).unwrap();
    writeln!(out, "  \"wall_ns\": {},", r.wall_ns).unwrap();
    writeln!(out, "  \"busy_ns\": {},", r.busy_ns).unwrap();
    writeln!(out, "  \"steals\": {},", r.steals).unwrap();
    writeln!(out, "  \"tokens_out\": {},", r.tokens_out).unwrap();
    writeln!(out, "  \"tokens_per_sec\": {:.1},", r.tokens_per_sec()).unwrap();
    writeln!(out, "  \"utilization\": {:.3},", r.utilization()).unwrap();
    match &r.chaos {
        Some(c) => {
            out.push_str("  \"chaos\": {\n");
            writeln!(out, "    \"faults_injected\": {},", c.faults_injected()).unwrap();
            writeln!(out, "    \"slot_faults\": {},", c.slot_faults).unwrap();
            writeln!(out, "    \"bus_faults\": {},", c.bus_faults).unwrap();
            writeln!(out, "    \"outages\": {},", c.outages).unwrap();
            writeln!(out, "    \"repairs\": {},", c.repairs).unwrap();
            writeln!(out, "    \"migrations\": {},", c.migrations).unwrap();
            writeln!(out, "    \"rescued_waves\": {},", c.rescued_waves).unwrap();
            writeln!(out, "    \"retries\": {},", c.retries).unwrap();
            writeln!(out, "    \"demotions\": {},", c.demotions).unwrap();
            writeln!(out, "    \"route_invalidations\": {}", c.route_invalidations).unwrap();
            out.push_str("  },\n");
        }
        None => out.push_str("  \"chaos\": null,\n"),
    }
    out.push_str("  \"scaling\": [\n");
    for (i, p) in scaling.iter().enumerate() {
        let comma = if i + 1 < scaling.len() { "," } else { "" };
        out.push_str("    {\n");
        writeln!(out, "      \"workers\": {},", p.workers).unwrap();
        writeln!(out, "      \"wall_ns\": {},", p.wall_ns).unwrap();
        writeln!(out, "      \"busy_ns\": {},", p.busy_ns).unwrap();
        writeln!(out, "      \"tokens_out\": {},", p.tokens_out).unwrap();
        writeln!(out, "      \"tokens_per_sec\": {:.1},", p.tokens_per_sec()).unwrap();
        writeln!(out, "      \"completed\": {},", p.completed).unwrap();
        writeln!(out, "      \"p50_ns\": {},", p.p50_ns).unwrap();
        writeln!(out, "      \"p95_ns\": {},", p.p95_ns).unwrap();
        writeln!(out, "      \"p99_ns\": {}", p.p99_ns).unwrap();
        writeln!(out, "    }}{comma}").unwrap();
    }
    out.push_str("  ],\n");
    out.push_str("  \"global\": {\n");
    stats_json(&mut out, "    ", &r.global);
    out.push_str("  },\n");
    out.push_str("  \"tenants\": [\n");
    for (i, t) in r.tenants.iter().enumerate() {
        let comma = if i + 1 < r.tenants.len() { "," } else { "" };
        out.push_str("    {\n");
        stats_json(&mut out, "      ", t);
        writeln!(out, "    }}{comma}").unwrap();
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{run_profile, standard_profile, ServeOptions};

    fn tiny_report() -> ServeReport {
        let profile = standard_profile(2, 3, 11);
        run_profile(&profile, &ServeOptions::default()).report
    }

    #[test]
    fn table_names_every_tenant_and_the_invariants() {
        let r = tiny_report();
        let t = serve_table(&r);
        for tenant in &r.tenants {
            assert!(t.contains(&tenant.name), "missing {}", tenant.name);
        }
        assert!(t.contains("global"));
        assert!(t.contains("p99 ms"));
        assert!(t.contains("lost requests 0"), "{t}");
        assert!(t.contains("dispatch: 1 worker(s)"), "{t}");
        assert!(t.contains("tokens/s"), "{t}");
    }

    #[test]
    fn json_is_structurally_sound_and_carries_the_schema() {
        let r = tiny_report();
        let scaling = [ScalePoint::from_report(&r)];
        let json = to_json(&r, 11, 2, 3, true, &scaling);
        assert!(json.starts_with("{\n") && json.ends_with("}\n"));
        assert!(json.contains("\"schema\": \"dataflow-accel-serve/v3\""));
        assert!(json.contains("\"chaos\": null"), "fault-free run");
        assert!(json.contains("\"empty\": false"), "tenants completed work");
        for field in ["\"p50_ns\"", "\"p95_ns\"", "\"p99_ns\""] {
            assert!(
                json.matches(field).count() >= r.tenants.len() + 2,
                "{field} missing"
            );
        }
        assert!(json.contains("\"lost\": 0"));
        assert!(json.contains("\"cache_hits\""));
        assert!(json.contains("\"workers\": 1"));
        assert!(json.contains("\"scaling\": ["));
        assert!(json.contains("\"tokens_per_sec\""));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert!(!json.contains("NaN") && !json.contains("inf"));
    }

    #[test]
    fn json_bucket_bounds_are_monotone_and_match_the_table_renderer() {
        // Satellite regression: bucket labels used to risk drifting
        // between JSON and tables because each side could recompute
        // them. Both now read Histogram::buckets(); assert the JSON
        // rows are exactly those rows (monotone, disjoint) and that the
        // table renderer prints the same bounds.
        let r = tiny_report();
        let json = to_json(&r, 11, 2, 3, true, &[]);
        let rows = r.global.latency.buckets();
        assert!(!rows.is_empty(), "profile completed requests");
        let expected: Vec<String> = rows
            .iter()
            .map(|(lo, hi, c)| format!("[{lo}, {hi}, {c}]"))
            .collect();
        let expected = format!("\"buckets\": [{}]", expected.join(", "));
        assert!(json.contains(&expected), "global buckets drifted:\n{json}");
        let mut prev_hi = None;
        for &(lo, hi, _) in &rows {
            assert!(lo <= hi);
            if let Some(p) = prev_hi {
                assert!(lo > p, "bucket [{lo}, {hi}] not monotone after {p}");
            }
            prev_hi = Some(hi);
        }
        let table = crate::report::obs::histogram_table("global", &r.global.latency);
        for &(lo, hi, c) in &rows {
            let row = format!("{lo:>20} {hi:>20} {c:>10}");
            assert!(table.contains(&row), "table missing {row:?}:\n{table}");
        }
    }

    #[test]
    fn empty_scaling_sweep_serializes_cleanly() {
        let r = tiny_report();
        let json = to_json(&r, 11, 2, 3, true, &[]);
        assert!(json.contains("\"scaling\": [\n  ],"));
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn zero_request_tenants_are_marked_empty_not_fast() {
        // Regression (satellite): a tenant that never completed a
        // request used to render "0.000 ms" quantiles — indistinguishable
        // from genuinely sub-microsecond service. Now the table shows
        // dashes and the JSON carries an explicit `"empty": true`.
        let mut r = tiny_report();
        r.tenants.push(crate::serve::TenantStats::named("idle"));
        let t = serve_table(&r);
        let idle_row = t.lines().find(|l| l.starts_with("idle")).expect("row");
        assert!(idle_row.contains('-'), "{idle_row}");
        assert!(!idle_row.contains("0.000"), "{idle_row}");
        let json = to_json(&r, 11, 2, 3, true, &[]);
        assert!(json.contains("\"empty\": true"), "{json}");
        // Non-empty tenants keep real numbers.
        assert!(json.contains("\"empty\": false"), "{json}");
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn chaos_counters_serialize_when_present() {
        let mut r = tiny_report();
        r.chaos = Some(crate::serve::ChaosStats {
            slot_faults: 1,
            bus_faults: 1,
            outages: 1,
            repairs: 3,
            migrations: 2,
            rescued_waves: 5,
            retries: 4,
            demotions: 2,
            route_invalidations: 6,
        });
        let t = serve_table(&r);
        assert!(t.contains("chaos: 3 fault(s)"), "{t}");
        assert!(t.contains("2 migration(s)"), "{t}");
        assert!(t.contains("5 wave(s) rescued"), "{t}");
        let json = to_json(&r, 11, 2, 3, true, &[]);
        assert!(json.contains("\"faults_injected\": 3"), "{json}");
        assert!(json.contains("\"rescued_waves\": 5"), "{json}");
        assert!(!json.contains("\"chaos\": null"), "{json}");
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn scaling_table_reports_every_worker_count() {
        let r = tiny_report();
        let mut p = ScalePoint::from_report(&r);
        let mut points = vec![p];
        p.workers = 2;
        p.wall_ns = p.wall_ns.max(2) / 2;
        points.push(p);
        let t = scaling_table(&points);
        assert!(t.contains("workers"));
        assert!(t.contains("speedup"));
        // Two data rows below the header.
        assert_eq!(t.lines().count(), 3, "{t}");
    }
}
