//! The elastic-run verdict: `ELASTIC_10.json` (schema
//! `dataflow-accel-elastic/v1`), written by `serve --elastic` **only**
//! when the rolling-repartition gate holds. The CLI refuses to write
//! the file otherwise, so the artifact's existence is itself the
//! claim; the JSON carries the evidence (repartition counters, the
//! policy, accounting, the digest-match verdict against the
//! static-allocation baseline) so CI can re-assert it without
//! re-running.

use crate::report::obs::format_event;
use crate::serve::{ElasticOutcome, ElasticPolicy};
use std::fmt::Write as _;

/// Everything the elastic gate checks, precomputed so the CLI and the
/// JSON writer cannot disagree about what passed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ElasticGate {
    /// ≥ 1 rolling repartition actually executed (the epoch loop
    /// changed the slot plan at least once).
    pub repartitioned: bool,
    /// ≥ 1 tenant promoted up the route lattice — the elastic run's
    /// whole point is that hot tenants climb off the fallback engine.
    pub promoted: bool,
    /// No request vanished: `lost == 0` for every tenant.
    pub zero_lost: bool,
    /// `completed + shed == submitted` globally.
    pub accounting_exact: bool,
    /// The dispatch schedule is identical to the static baseline's —
    /// repartitioning must never leak into scheduling decisions.
    pub dispatch_match: bool,
    /// Every completed request's output digest is byte-identical to
    /// the static-allocation baseline's, and both runs completed the
    /// same request set.
    pub digest_match: bool,
    /// When `digest_match` is false: the first `(tenant, seq)` — in
    /// key order — whose digest differs (or exists on one side only),
    /// so the verdict can dump that request's flight-recorder timeline
    /// instead of a bare "digests diverged".
    pub first_divergence: Option<(usize, usize)>,
}

impl ElasticGate {
    /// Evaluate the gate over an elastic run and its static-allocation
    /// baseline (same profile, same options,
    /// [`ElasticPolicy::static_allocation`]).
    pub fn check(elastic: &ElasticOutcome, baseline: &ElasticOutcome) -> Self {
        let g = &elastic.report.global;
        let first_divergence = first_divergence(elastic, baseline);
        ElasticGate {
            repartitioned: elastic.elastic.repartitions >= 1,
            promoted: elastic.elastic.promotions >= 1,
            zero_lost: elastic.report.tenants.iter().all(|t| t.lost() == 0) && g.lost() == 0,
            accounting_exact: g.completed + g.shed() == g.submitted,
            dispatch_match: elastic.dispatches == baseline.dispatches,
            digest_match: first_divergence.is_none(),
            first_divergence,
        }
    }

    pub fn passed(&self) -> bool {
        self.repartitioned
            && self.promoted
            && self.zero_lost
            && self.accounting_exact
            && self.dispatch_match
            && self.digest_match
    }

    /// The gates that failed, for the CLI's refusal message.
    pub fn failures(&self) -> Vec<&'static str> {
        let mut v = Vec::new();
        if !self.repartitioned {
            v.push("no rolling repartition executed (demand never reshaped the slot plan)");
        }
        if !self.promoted {
            v.push("no tenant promoted up the route lattice");
        }
        if !self.zero_lost {
            v.push("requests were lost (neither completed nor shed)");
        }
        if !self.accounting_exact {
            v.push("completed + shed != submitted");
        }
        if !self.dispatch_match {
            v.push("dispatch schedule diverges from the static-allocation baseline");
        }
        if !self.digest_match {
            v.push("output digests diverge from the static-allocation baseline");
        }
        v
    }
}

/// First `(tenant, seq)` — in `BTreeMap` key order — whose output
/// digest differs between the two runs, or which completed in one run
/// but not the other. `None` when the maps are identical.
fn first_divergence(elastic: &ElasticOutcome, baseline: &ElasticOutcome) -> Option<(usize, usize)> {
    let e = &elastic.output_digests;
    let b = &baseline.output_digests;
    // Union of both key sets, sorted, so a request that completed in
    // only one run still surfaces in true key order.
    e.keys()
        .chain(b.keys())
        .copied()
        .collect::<std::collections::BTreeSet<(usize, usize)>>()
        .into_iter()
        .find(|k| e.get(k) != b.get(k))
}

/// Serialize the elastic verdict (schema `dataflow-accel-elastic/v1`).
/// Callers gate on [`ElasticGate::passed`] before writing this to
/// disk; the serializer itself is total so tests can render failing
/// gates.
pub fn to_json(
    gate: &ElasticGate,
    policy: &ElasticPolicy,
    elastic: &ElasticOutcome,
    seed: u64,
    quick: bool,
) -> String {
    let g = &elastic.report.global;
    let e = &elastic.elastic;
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"dataflow-accel-elastic/v1\",\n");
    writeln!(out, "  \"seed\": {seed},").unwrap();
    writeln!(out, "  \"quick\": {quick},").unwrap();
    writeln!(out, "  \"passed\": {},", gate.passed()).unwrap();
    writeln!(out, "  \"digest_match\": {},", gate.digest_match).unwrap();
    writeln!(out, "  \"dispatch_match\": {},", gate.dispatch_match).unwrap();
    writeln!(out, "  \"submitted\": {},", g.submitted).unwrap();
    writeln!(out, "  \"completed\": {},", g.completed).unwrap();
    writeln!(out, "  \"shed\": {},", g.shed()).unwrap();
    writeln!(out, "  \"lost\": {},", g.lost()).unwrap();
    writeln!(out, "  \"verified\": {},", g.verified).unwrap();
    writeln!(out, "  \"ticks\": {},", elastic.report.ticks).unwrap();
    out.push_str("  \"policy\": {\n");
    writeln!(out, "    \"initial_slots\": {},", policy.initial_slots).unwrap();
    writeln!(out, "    \"initial_channels\": {},", policy.initial_channels).unwrap();
    writeln!(out, "    \"epoch_ticks\": {},", policy.epoch_ticks).unwrap();
    writeln!(out, "    \"drain_ticks\": {},", policy.drain_ticks).unwrap();
    writeln!(out, "    \"hot_requests\": {}", policy.hot_requests).unwrap();
    out.push_str("  },\n");
    out.push_str("  \"repartition\": {\n");
    writeln!(out, "    \"epochs\": {},", e.epochs).unwrap();
    writeln!(out, "    \"repartitions\": {},", e.repartitions).unwrap();
    writeln!(out, "    \"drains\": {},", e.drains).unwrap();
    writeln!(out, "    \"restores\": {},", e.restores).unwrap();
    writeln!(out, "    \"migrated_waves\": {},", e.migrated_waves).unwrap();
    writeln!(out, "    \"delayed_waves\": {},", e.delayed_waves).unwrap();
    writeln!(out, "    \"promotions\": {},", e.promotions).unwrap();
    writeln!(out, "    \"targeted_invalidations\": {}", e.targeted_invalidations).unwrap();
    out.push_str("  },\n");
    let promoted: Vec<String> = elastic
        .promoted_tenants
        .iter()
        .map(|t| t.to_string())
        .collect();
    writeln!(out, "  \"promoted_tenants\": [{}],", promoted.join(", ")).unwrap();
    writeln!(out, "  \"requests_digested\": {}", elastic.output_digests.len()).unwrap();
    out.push_str("}\n");
    out
}

/// The human verdict line the CLI prints alongside the table.
pub fn elastic_summary(gate: &ElasticGate, elastic: &ElasticOutcome) -> String {
    let e = &elastic.elastic;
    let mut out = String::new();
    writeln!(
        out,
        "elastic gate: {} | {} epoch(s), {} repartition(s), {} promotion(s), \
         {} request(s) digest-checked against the static-allocation baseline",
        if gate.passed() { "PASS" } else { "FAIL" },
        e.epochs,
        e.repartitions,
        e.promotions,
        elastic.output_digests.len()
    )
    .unwrap();
    for f in gate.failures() {
        writeln!(out, "  gate failure: {f}").unwrap();
    }
    if let Some((tenant, seq)) = gate.first_divergence {
        writeln!(
            out,
            "  first divergence: tenant {tenant} seq {seq} — flight-recorder tail for \
             tenant {tenant}:"
        )
        .unwrap();
        let tail = elastic.flight.timeline(tenant as u32);
        if tail.is_empty() {
            writeln!(out, "    (flight recorder empty for this tenant)").unwrap();
        }
        for ev in &tail {
            writeln!(out, "    {}", format_event(ev)).unwrap();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{fairness_profile, run_profile_elastic, ServeOptions};

    fn runs() -> (ElasticPolicy, ElasticOutcome, ElasticOutcome) {
        // Small batches keep the heavy tenant dispatching past the
        // first epoch boundary (default max_batch would drain the whole
        // profile before tick 4 and the epoch loop would never fire).
        let p = fairness_profile(2, 5, 17);
        let opts = ServeOptions {
            cfg: crate::serve::ServeCfg {
                max_batch: 4,
                ..Default::default()
            },
            ..ServeOptions::default()
        };
        let policy = ElasticPolicy::scarce();
        let baseline = run_profile_elastic(&p, &opts, &policy.static_allocation());
        let elastic = run_profile_elastic(&p, &opts, &policy);
        (policy, elastic, baseline)
    }

    #[test]
    fn gate_passes_on_the_fairness_profile_and_json_carries_the_verdict() {
        let (policy, elastic, baseline) = runs();
        let gate = ElasticGate::check(&elastic, &baseline);
        assert!(gate.passed(), "{:?}", gate.failures());
        let json = to_json(&gate, &policy, &elastic, 17, true);
        assert!(json.contains("\"schema\": \"dataflow-accel-elastic/v1\""));
        assert!(json.contains("\"passed\": true"));
        assert!(json.contains("\"digest_match\": true"));
        assert!(json.contains("\"dispatch_match\": true"));
        assert!(json.contains("\"lost\": 0"));
        assert!(!json.contains("\"repartitions\": 0"));
        assert!(!json.contains("\"promotions\": 0"));
        assert!(!json.contains("\"promoted_tenants\": []"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        let line = elastic_summary(&gate, &elastic);
        assert!(line.contains("PASS"), "{line}");
    }

    #[test]
    fn gate_fails_loudly_when_nothing_repartitions_or_digests_break() {
        let (_, elastic, baseline) = runs();
        // The static baseline gated against itself never repartitions:
        // the whole elastic story is missing, and the gate says which
        // halves.
        let inert = ElasticGate::check(&baseline, &baseline);
        assert!(!inert.passed());
        assert!(!inert.repartitioned);
        assert!(!inert.promoted);
        assert!(inert.digest_match, "self-comparison cannot diverge");
        // ...and a doctored digest verdict fails the gate loudly.
        let mut wrong = ElasticGate::check(&elastic, &baseline);
        wrong.digest_match = false;
        assert!(!wrong.passed());
        let line = elastic_summary(&wrong, &elastic);
        assert!(line.contains("FAIL"), "{line}");
        assert!(line.contains("diverge"), "{line}");
        let json = to_json(&wrong, &ElasticPolicy::scarce(), &elastic, 17, true);
        assert!(json.contains("\"passed\": false"));
    }

    #[test]
    fn digest_gate_failure_names_the_divergence_and_dumps_its_timeline() {
        let (_, mut elastic, baseline) = runs();
        // Deliberately perturb one output digest: the gate must fail,
        // name exactly this (tenant, seq), and dump that tenant's
        // flight-recorder tail.
        let (&key, &val) = elastic.output_digests.iter().next().unwrap();
        elastic.output_digests.insert(key, val ^ 0xdead_beef);
        let gate = ElasticGate::check(&elastic, &baseline);
        assert!(!gate.passed());
        assert!(!gate.digest_match);
        assert_eq!(gate.first_divergence, Some(key));
        let line = elastic_summary(&gate, &elastic);
        assert!(line.contains("FAIL"), "{line}");
        let (tenant, seq) = key;
        assert!(
            line.contains(&format!("first divergence: tenant {tenant} seq {seq}")),
            "{line}"
        );
        // The flight recorder recorded this tenant's run, so the dump
        // has at least one indented timeline line.
        assert!(line.lines().any(|l| l.starts_with("    ")), "{line}");
        // A request missing from one side entirely is also a divergence.
        elastic.output_digests.remove(&key);
        let missing = ElasticGate::check(&elastic, &baseline);
        assert_eq!(missing.first_divergence, Some(key));
        assert!(!missing.digest_match);
    }
}
