//! `dataflow-accel` — CLI for the static dataflow accelerator.
//!
//! ```text
//! dataflow-accel run <bench> [--n 16] [--seed 7] [--engine token|fsm|dynamic]
//! dataflow-accel compile <bench> [--emit asm|vhdl|c|resources]
//! dataflow-accel opt [bench] [--level none|default|aggressive] [--out OPT_5.json]
//! dataflow-accel place <bench> [--shards K] [--channels N] [--check] [--reconfig]
//! dataflow-accel stream <bench|saxpy> [--waves 8] [--n 8] [--seed 7]
//! dataflow-accel stream --table [--waves 8] [--n 8] [--seed 7]
//! dataflow-accel bench [--quick] [--no-fuse] [--items 64] [--n 16] [--seed 7] [--out BENCH_7.json]
//! dataflow-accel serve [--quick] [--seed 7] [--scale 24] [--n 8]
//!                      [--arrival closed|open|burst] [--workers N] [--scale-workers]
//!                      [--trace] [--trace-out OBS_9.json] [--out SERVE_6.json]
//! dataflow-accel serve --chaos [--quick] [--seed 7] [--scale 16] [--n 8]
//!                      [--out CHAOS_8.json]
//! dataflow-accel serve --elastic [--quick] [--seed 7] [--scale 16] [--n 8]
//!                      [--out ELASTIC_10.json]
//! dataflow-accel trace --bench <slug|saxpy> [--items 8] [--n 8] [--seed 7]
//!                      [--out OBS_9.json] [--chrome PATH]
//! dataflow-accel trace --serve [--quick] [--seed 7] [--workers N] [--scale 8] [--n 8]
//!                      [--out OBS_9.json] [--chrome PATH]
//! dataflow-accel bench --trace-overhead [--quick] [--items 64] [--n 16] [--seed 7]
//! dataflow-accel table1 [--fig8]
//! dataflow-accel sweep [--bench all] [--requests 64] [--n 16] [--engine native|xla]
//!                      [--workers 4] [--batch 8] [--stream]
//! dataflow-accel info
//! ```

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::coordinator::{Coordinator, Engine, Request};
use dataflow_accel::fabric::{self, FabricTopology};
use dataflow_accel::util::args::Args;
use dataflow_accel::{estimate, frontend, report, sim, vhdl};

fn main() {
    let args = Args::parse(
        std::env::args().skip(1),
        &[
            "fig8",
            "verbose",
            "check",
            "reconfig",
            "table",
            "stream",
            "quick",
            "scale-workers",
            "no-fuse",
            "chaos",
            "elastic",
            "trace",
            "trace-overhead",
            "serve",
        ],
    );
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "compile" => cmd_compile(&args),
        "opt" => cmd_opt(&args),
        "place" => cmd_place(&args),
        "stream" => cmd_stream(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "trace" => cmd_trace(&args),
        "table1" => {
            if args.has("fig8") {
                print!("{}", report::fig8_csv());
            } else {
                print!("{}", report::table1());
            }
        }
        "sweep" => cmd_sweep(&args),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: dataflow-accel <run|compile|opt|place|stream|bench|serve|trace|table1|sweep|info> [options]\n\
                 opt: run the DFG optimizer pipeline over the benchmark graphs \n\
                 \x20 [bench]       show one benchmark's before/after graphs + pass report\n\
                 \x20 --level L     none | default | aggressive (default: default)\n\
                 \x20 --out PATH    write the JSON report (default OPT_5.json; whole-suite mode)\n\
                 place: map a benchmark onto the physical fabric model \n\
                 \x20 --shards K    size the fabric to ~1/K of the graph (forces partitioning)\n\
                 \x20 --channels N  override the bus-channel pool\n\
                 \x20 --check       run sharded + whole-graph sims and compare outputs\n\
                 \x20 --reconfig    time-multiplex the shards on one fabric, report swap cost\n\
                 stream: wave-pipelined execution over a resident graph \n\
                 \x20 --waves K     number of independent input waves (default 8)\n\
                 \x20 --table       print the streamed-vs-run-to-completion throughput table\n\
                 bench: scalar vs streamed vs lane engines over all seven benchmarks \n\
                 \x20 --quick       reduced iteration counts (the CI smoke job)\n\
                 \x20 --items B     batch items per benchmark (default 64; 8 with --quick)\n\
                 \x20 --no-fuse     compile the lane program without superinstruction fusion\n\
                 \x20 --out PATH    write the JSON trajectory (default BENCH_7.json)\n\
                 serve: multi-tenant service tier over the fixed 3-tenant workload mix \n\
                 \x20 --quick       reduced request counts (the CI smoke job)\n\
                 \x20 --scale S     per-weight request multiplier (default 24; 4 with --quick)\n\
                 \x20 --n N         workload size per request (default 8; 4 with --quick)\n\
                 \x20 --seed S      load-profile seed (same seed = same request trace)\n\
                 \x20 --arrival M   closed (default), open, or burst (open-loop ramp) arrivals\n\
                 \x20 --workers N   dispatch batches across N work-stealing workers (default 1)\n\
                 \x20 --scale-workers  sweep worker counts 1,2,..,max(4,N); verify identical\n\
                 \x20                  results per count, emit the scaling curve\n\
                 \x20 --chaos       run the 10:1 fairness profile under a seeded fabric fault\n\
                 \x20               schedule; refuse CHAOS_8.json unless zero requests were\n\
                 \x20               lost and outputs match the fault-free baseline byte-for-byte\n\
                 \x20 --elastic     start the pool on a scarce fabric slice and repartition it\n\
                 \x20               online from observed demand; refuse ELASTIC_10.json unless\n\
                 \x20               a rolling repartition ran, a tenant was promoted, zero\n\
                 \x20               requests were lost, and outputs match the static-allocation\n\
                 \x20               baseline byte-for-byte\n\
                 \x20 --out PATH    write the JSON report (default SERVE_6.json; CHAOS_8.json\n\
                 \x20               with --chaos, ELASTIC_10.json with --elastic)\n\
                 \x20 --trace       record the span trace (virtual ticks) during the run and\n\
                 \x20               write it as OBS_9.json (override with --trace-out PATH)\n\
                 trace: deterministic observability capture (OBS_9.json) \n\
                 \x20 --bench B     profile the token/lane/stream engines over one benchmark;\n\
                 \x20               refuses the artifact if any engine's profiled firing\n\
                 \x20               totals disagree with its unprofiled run\n\
                 \x20 --serve       run the service tier with the span trace attached\n\
                 \x20 --chrome PATH also write Chrome trace_event JSON (chrome://tracing)\n\
                 bench --trace-overhead: A/B the lane engine profiled vs not, print overhead\n\
                 sweep: --stream routes batches through resident streaming sessions\n\
                 benchmarks: {} saxpy (stream/bench only)",
                BenchId::ALL.map(|b| b.slug()).join(" ")
            );
        }
    }
}

fn bench_arg(args: &Args) -> BenchId {
    let name = args
        .positional
        .get(1)
        .unwrap_or_else(|| panic!("missing benchmark name"));
    BenchId::from_slug(name).unwrap_or_else(|| panic!("unknown benchmark `{name}`"))
}

fn cmd_run(args: &Args) {
    let bench = bench_arg(args);
    let n = args.get_usize("n", 16);
    let seed = args.get_u64("seed", 7);
    let g = bench_defs::build(bench);
    let wl = bench_defs::workload(bench, n, seed);
    let cfg = wl.sim_config();
    let out = match args.get_or("engine", "token").as_str() {
        "token" => sim::run_token(&g, &cfg),
        "fsm" => {
            let mut cfg = cfg.clone();
            cfg.max_cycles *= 4;
            sim::run_fsm(&g, &cfg)
        }
        "dynamic" => sim::run_dynamic(&g, &cfg, 4),
        other => panic!("unknown engine `{other}`"),
    };
    println!(
        "{}: {} nodes, {} arcs | {} cycles, {} firings, quiescent={}",
        bench.slug(),
        g.n_nodes(),
        g.n_arcs(),
        out.cycles,
        out.firings,
        out.quiescent
    );
    for (port, want) in &wl.expect {
        let got = out.stream(port);
        let ok = got == want.as_slice();
        println!(
            "  {port}: {got:?} {}",
            if ok { "(verified)" } else { "(MISMATCH)" }
        );
    }
}

fn cmd_compile(args: &Args) {
    let bench = bench_arg(args);
    match args.get_or("emit", "asm").as_str() {
        "asm" => print!("{}", bench_defs::asm_source(bench)),
        "c" => print!("{}", bench_defs::c_source(bench)),
        "vhdl" => {
            // Compile the C source through the frontend, then emit VHDL —
            // the paper's full future-work chain.
            let g = frontend::compile(bench.slug(), bench_defs::c_source(bench))
                .expect("benchmark C source compiles");
            print!("{}", vhdl::generate(&g).render());
        }
        "resources" => {
            let g = bench_defs::build(bench);
            let r = estimate::estimate(&g);
            let t = estimate::estimate_trimmed(&g);
            println!(
                "{}: FF {} (trimmed {}), LUT {}, slices {}, bram {} bits, fmax {:.1} MHz",
                bench.slug(),
                r.ff,
                t.ff,
                r.lut,
                r.slices,
                r.bram_bits,
                r.fmax_mhz
            );
        }
        other => panic!("unknown --emit `{other}`"),
    }
}

fn cmd_opt(args: &Args) {
    use dataflow_accel::opt::{optimize, OptLevel};
    let level_name = args.get_or("level", "default");
    let level = OptLevel::from_name(&level_name)
        .unwrap_or_else(|| panic!("unknown --level `{level_name}` (none|default|aggressive)"));

    if let Some(which) = args.positional.get(1) {
        // Single-benchmark deep dive: before/after graphs + pass report
        // for the frontend-lowered form (hand-built for saxpy).
        let (raw, label) = if which.as_str() == "saxpy" {
            (bench_defs::saxpy::build(), "built")
        } else {
            let bench = BenchId::from_slug(which)
                .unwrap_or_else(|| panic!("unknown benchmark `{which}`"));
            (
                frontend::compile_with(bench.slug(), bench_defs::c_source(bench), OptLevel::None)
                    .expect("benchmark C source compiles"),
                "lowered",
            )
        };
        let (og, report) = optimize(&raw, level);
        println!("=== {which} ({label}, raw: {} nodes, {} arcs) ===", raw.n_nodes(), raw.n_arcs());
        print!("{}", dataflow_accel::asm::print(&raw));
        println!("=== optimized @ {level} ({} nodes, {} arcs) ===", og.n_nodes(), og.n_arcs());
        print!("{}", dataflow_accel::asm::print(&og));
        print!("{report}");
        let (rb, ra) = (estimate::estimate(&raw), estimate::estimate(&og));
        println!(
            "resources: FF {} -> {}, LUT {} -> {}, fmax {:.1} -> {:.1} MHz",
            rb.ff, ra.ff, rb.lut, ra.lut, rb.fmax_mhz, ra.fmax_mhz
        );
        return;
    }

    let out_path = args.get_or("out", "OPT_5.json");
    let rows = report::opt::opt_rows(level);
    print!("{}", report::opt::render_table(&rows, level));
    // Equivalence gates the trajectory file: numbers from a rewrite
    // that changed any named output stream must never land in
    // OPT_*.json.
    let broken: Vec<String> = rows
        .iter()
        .filter(|r| !r.verified)
        .map(|r| format!("{}/{}", r.name, r.source))
        .collect();
    if !broken.is_empty() {
        eprintln!("opt: EQUIVALENCE FAILURES: {}", broken.join(", "));
        eprintln!("opt: refusing to write {out_path}");
        std::process::exit(1);
    }
    let json = report::opt::to_json(&rows, level);
    std::fs::write(&out_path, &json).unwrap_or_else(|e| panic!("cannot write `{out_path}`: {e}"));
    println!("wrote {out_path}");
}

fn cmd_place(args: &Args) {
    let bench = bench_arg(args);
    let g = bench_defs::build(bench);
    let mut topo = match args.get("shards") {
        Some(k) => {
            let k: usize = k.parse().unwrap_or_else(|_| panic!("--shards wants a number"));
            FabricTopology::sized_for_shards(&g, k)
        }
        None => FabricTopology::paper(),
    };
    if let Some(ch) = args.get("channels") {
        topo.channels = ch.parse().unwrap_or_else(|_| panic!("--channels wants a number"));
    }
    print!("{}", report::placement_table(&g, &topo));

    if args.has("check") || args.has("reconfig") {
        let n = args.get_usize("n", 8);
        let seed = args.get_u64("seed", 7);
        let wl = bench_defs::workload(bench, n, seed);
        let cfg = wl.sim_config();
        let whole = sim::run_token(&g, &cfg);
        match fabric::partition(&g, &topo) {
            Ok(plan) => {
                if args.has("check") {
                    let sharded = fabric::run_sharded(&plan, &cfg);
                    let ok = sharded.outputs == whole.outputs;
                    println!(
                        "check: {} shard(s), outputs {} whole-graph TokenSim",
                        plan.n_shards(),
                        if ok { "MATCH" } else { "DIFFER from" }
                    );
                }
                if args.has("reconfig") {
                    let (out, stats) = fabric::run_reconfig(&plan, &topo, &cfg);
                    let ok = out.outputs == whole.outputs;
                    println!(
                        "reconfig: {} context load(s), {} reconfig + {} active cycles, \
                         outputs {}",
                        stats.swaps,
                        stats.reconfig_cycles,
                        stats.active_cycles,
                        if ok { "MATCH" } else { "DIFFER" }
                    );
                }
            }
            Err(e) => println!("check: unpartitionable ({e})"),
        }
    }
}

fn cmd_stream(args: &Args) {
    let waves = args.get_usize("waves", 8);
    let n = args.get_usize("n", 8);
    let seed = args.get_u64("seed", 7);
    if args.has("table") {
        print!("{}", report::throughput_table(waves, n, seed));
        return;
    }
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or_else(|| panic!("stream wants a benchmark name or --table"));

    // (graph, waves, expected z-streams keyed per wave+port)
    let (g, wave_inputs, expects): (
        dataflow_accel::dfg::Graph,
        Vec<sim::WaveInput>,
        Vec<std::collections::BTreeMap<String, Vec<dataflow_accel::dfg::Word>>>,
    ) = if which == "saxpy" {
        let g = bench_defs::saxpy::build();
        let mut ws = Vec::new();
        let mut ex = Vec::new();
        for i in 0..waves {
            let (w, z) = bench_defs::saxpy::wave(n, seed.wrapping_add(i as u64));
            ws.push(w);
            ex.push(std::collections::BTreeMap::from([("z".to_string(), z)]));
        }
        (g, ws, ex)
    } else {
        let bench = BenchId::from_slug(which)
            .unwrap_or_else(|| panic!("unknown benchmark `{which}`"));
        let g = bench_defs::build(bench);
        let wls = bench_defs::wave_workloads(bench, waves, n, seed);
        let ws = wls.iter().map(|w| w.inject.clone()).collect();
        let ex = wls.into_iter().map(|w| w.expect).collect();
        (g, ws, ex)
    };

    let mut session = sim::StreamSession::new(&g);
    let mode = session.mode();
    for w in &wave_inputs {
        session.admit(w).expect("wave admission");
    }
    session.run(1_000_000u64.saturating_mul(waves as u64));
    let m = session.metrics();
    println!(
        "{}: {} waves ({:?} admission) | {} rounds, {} firings, {} tokens out",
        g.name, m.waves_completed, mode, m.rounds, m.firings, m.tokens_out
    );
    println!(
        "  throughput {:.4} tokens/cycle | occupancy {:.1}% | tag stalls {}",
        m.tokens_per_cycle(),
        100.0 * m.occupancy(g.n_nodes()),
        m.tag_stalls
    );
    let mut ok = 0usize;
    for (i, expect) in expects.iter().enumerate() {
        let outs = session.wave_outputs(i as u32);
        let verified = expect
            .iter()
            .all(|(port, want)| outs.get(port).map(|v| v == want).unwrap_or(false));
        if verified {
            ok += 1;
        } else {
            println!("  wave {i}: MISMATCH (got {outs:?}, want {expect:?})");
        }
    }
    println!("  verified {ok}/{} waves", expects.len());
    println!("  wave latency histogram (rounds):");
    for (lo, hi, count) in m.latency_histogram(6) {
        println!("    [{lo:>6}, {hi:>6})  {}", "#".repeat(count));
    }
}

fn cmd_bench(args: &Args) {
    if args.has("trace-overhead") {
        cmd_bench_trace_overhead(args);
        return;
    }
    let quick = args.has("quick");
    let items = args.get_usize("items", if quick { 8 } else { 64 });
    let n = args.get_usize("n", if quick { 4 } else { 16 });
    let seed = args.get_u64("seed", 7);
    let out_path = args.get_or("out", "BENCH_7.json");
    let mut cfg = report::perf::PerfCfg::new(items, n, seed, quick);
    cfg.fuse = !args.has("no-fuse");
    let rows = report::perf::run_suite(&cfg);
    print!("{}", report::perf::render_table(&rows));
    // Verification gates the trajectory file: numbers from an engine
    // whose outputs diverged must never land in BENCH_*.json.
    let mut unverified = Vec::new();
    for r in &rows {
        for e in r.engines.iter().filter(|e| !e.verified) {
            unverified.push(format!("{}/{}", r.name, e.engine));
        }
    }
    if !unverified.is_empty() {
        eprintln!("bench: UNVERIFIED engine outputs: {}", unverified.join(", "));
        eprintln!("bench: refusing to write {out_path}");
        std::process::exit(1);
    }
    // Same gate for the summary statistics: a non-finite or non-positive
    // geomean means the harness itself misbehaved, and a trajectory file
    // with a poisoned headline number is worse than none.
    let geo_all = report::perf::geomean_lane_speedup(&rows, false);
    let geo_pipe = report::perf::geomean_lane_speedup(&rows, true);
    for (label, v) in [
        ("geomean_lane_speedup", geo_all),
        ("geomean_lane_speedup_pipelineable", geo_pipe),
    ] {
        if !v.is_finite() || v <= 0.0 {
            eprintln!("bench: degenerate {label} = {v}");
            eprintln!("bench: refusing to write {out_path}");
            std::process::exit(1);
        }
    }
    let json = report::perf::to_json(&rows, &cfg);
    std::fs::write(&out_path, &json).unwrap_or_else(|e| panic!("cannot write `{out_path}`: {e}"));
    println!("wrote {out_path}");
}

fn cmd_serve(args: &Args) {
    use dataflow_accel::obs::{self, ObsArtifact, TraceBuf};
    use dataflow_accel::serve::{self, Arrival};
    use std::sync::Arc;
    if args.has("chaos") {
        cmd_serve_chaos(args);
        return;
    }
    if args.has("elastic") {
        cmd_serve_elastic(args);
        return;
    }
    let quick = args.has("quick");
    let seed = args.get_u64("seed", 7);
    let scale = args.get_usize("scale", if quick { 4 } else { 24 });
    let n = args.get_usize("n", if quick { 4 } else { 8 });
    let workers = args.get_usize("workers", 1).max(1);
    let scale_workers = args.has("scale-workers");
    let tracing = args.has("trace");
    let trace_out = args.get_or("trace-out", "OBS_9.json");
    let out_path = args.get_or("out", "SERVE_6.json");
    let mut profile = serve::standard_profile(scale, n, seed);
    match args.get_or("arrival", "closed").as_str() {
        "closed" => {}
        "open" => profile.arrival = Arrival::Open { burst: 4 },
        "burst" => {
            let peak = if scale_workers { workers.max(4) } else { workers };
            profile.arrival = serve::burst_series(peak);
        }
        other => panic!("unknown --arrival `{other}` (closed|open|burst)"),
    }
    let refuse = |msg: String| {
        eprintln!("serve: {msg}");
        eprintln!("serve: refusing to write {out_path}");
        std::process::exit(1);
    };

    // Worker counts to run: always the 1-worker reference first. The
    // sweep doubles up to max(4, --workers), so the curve always has
    // at least three points (1, 2, 4).
    let mut counts = vec![1usize];
    if scale_workers {
        let cap = workers.max(4);
        let mut w = 2;
        while w < cap {
            counts.push(w);
            w *= 2;
        }
        counts.push(cap);
    } else if workers > 1 {
        counts.push(workers);
    }

    // Service invariants gate the trajectory file: every submitted
    // request must be completed or explicitly shed, every completed
    // request's outputs must have verified against its reference, and
    // every multi-worker run's per-request result digests must be
    // byte-identical to the 1-worker reference — numbers from a
    // lossy, wrong, or schedule-dependent service tier must never
    // land in SERVE_*.json.
    let mut scaling: Vec<report::ScalePoint> = Vec::new();
    let mut baseline_digests = None;
    let mut last = None;
    let mut trace_buf: Option<Arc<TraceBuf>> = None;
    for &w in &counts {
        let tb = tracing.then(|| Arc::new(TraceBuf::new(TraceBuf::DEFAULT_CAPACITY)));
        let opts = serve::ServeOptions {
            workers: w,
            trace: tb.clone(),
            ..serve::ServeOptions::default()
        };
        let outcome = serve::run_profile(&profile, &opts);
        let report = &outcome.report;
        if report.global.lost() != 0 {
            refuse(format!(
                "workers {w}: {} request(s) lost (submitted {} != completed {} + shed {})",
                report.global.lost(),
                report.global.submitted,
                report.global.completed,
                report.global.shed()
            ));
        }
        if report.global.verified != report.global.completed {
            refuse(format!(
                "workers {w}: {} completed request(s) failed verification",
                report.global.completed - report.global.verified
            ));
        }
        match &baseline_digests {
            None => baseline_digests = Some(outcome.digests.clone()),
            Some(base) => {
                if *base != outcome.digests {
                    let differ = outcome
                        .digests
                        .iter()
                        .filter(|(k, v)| base.get(k) != Some(v))
                        .count();
                    refuse(format!(
                        "workers {w}: results diverged from the 1-worker reference \
                         ({differ} of {} digests differ)",
                        base.len()
                    ));
                }
            }
        }
        scaling.push(report::ScalePoint::from_report(report));
        last = Some(outcome);
        trace_buf = tb;
    }

    let outcome = last.expect("at least the 1-worker run");
    let report = &outcome.report;
    print!("{}", report::serve_table(report));
    if counts.len() > 1 {
        print!("{}", report::scaling_table(&scaling));
        println!("scaling verified: results byte-identical across worker counts {counts:?}");
    }
    let json = report::serve::to_json(report, seed, scale, n, quick, &scaling);
    std::fs::write(&out_path, &json).unwrap_or_else(|e| panic!("cannot write `{out_path}`: {e}"));
    println!("wrote {out_path}");
    // The span trace of the final worker-count run. No wall-clock
    // sidecar: the artifact is a pure function of (profile, workers'
    // dispatch order), so the same command at any worker count writes a
    // byte-identical file — the CI smoke job asserts exactly that.
    if let Some(buf) = trace_buf {
        let events = buf.drain_sorted();
        print!("{}", report::demotion_ledger(&events));
        let art = ObsArtifact {
            source: "serve",
            events: &events,
            profiles: &[],
            families: &[],
            dropped: buf.dropped(),
            wall_clock_ns: None,
        };
        std::fs::write(&trace_out, obs::obs_json(&art))
            .unwrap_or_else(|e| panic!("cannot write `{trace_out}`: {e}"));
        println!("wrote {trace_out} ({} spans)", events.len());
    }
}

/// `serve --chaos`: the 10:1 fairness profile under a seeded fabric
/// fault schedule, gated against a fault-free baseline of the *same*
/// runner. The zero-lost-requests gate refuses to write CHAOS_8.json
/// unless every fault kind was injected, nothing was lost, accounting
/// is exact, and every completed request's output digest is
/// byte-identical to the baseline's.
fn cmd_serve_chaos(args: &Args) {
    use dataflow_accel::fabric::FaultPlan;
    use dataflow_accel::serve;
    let quick = args.has("quick");
    let seed = args.get_u64("seed", 7);
    let scale = args.get_usize("scale", if quick { 4 } else { 16 });
    let n = args.get_usize("n", if quick { 4 } else { 8 });
    let out_path = args.get_or("out", "CHAOS_8.json");
    let profile = serve::fairness_profile(scale, n, seed);
    // Small batches keep the heavy tenant dispatching well past the
    // seeded fault window (ticks 2–8), so faults land on live traffic
    // instead of after the profile drained.
    let opts = serve::ServeOptions {
        cfg: serve::ServeCfg {
            max_batch: 4,
            ..serve::ServeCfg::default()
        },
        ..serve::ServeOptions::default()
    };
    let plan = FaultPlan::seeded(seed, opts.pool_size);
    println!(
        "chaos: seed {seed}, {} fault event(s) over {} instance(s) \
         (slot {}, bus {}, outage {}, repair {})",
        plan.events().len(),
        opts.pool_size,
        plan.counts().slot,
        plan.counts().bus,
        plan.counts().outage,
        plan.counts().repair
    );
    let baseline = serve::run_profile_chaos(&profile, &opts, &FaultPlan::empty());
    let faulted = serve::run_profile_chaos(&profile, &opts, &plan);
    print!("{}", report::serve_table(&faulted.report));
    let gate = report::ChaosGate::check(&plan, &faulted, &baseline);
    print!("{}", report::chaos_summary(&gate, &faulted));
    if !gate.passed() {
        eprintln!("serve: chaos gate failed");
        eprintln!("serve: refusing to write {out_path}");
        std::process::exit(1);
    }
    let json = report::chaos::to_json(&gate, &plan, &faulted, seed, quick);
    std::fs::write(&out_path, &json).unwrap_or_else(|e| panic!("cannot write `{out_path}`: {e}"));
    println!("wrote {out_path}");
}

/// `serve --elastic`: the 10:1 fairness profile on a deliberately
/// scarce fabric slice, reshaped online by the load-driven
/// repartitioner and gated against a static-allocation baseline of the
/// *same* runner. The gate refuses to write ELASTIC_10.json unless at
/// least one rolling repartition ran, at least one tenant was promoted
/// up the route lattice, nothing was lost, accounting is exact, and
/// both the dispatch schedule and every completed request's output
/// digest are byte-identical to the baseline's.
fn cmd_serve_elastic(args: &Args) {
    use dataflow_accel::serve;
    let quick = args.has("quick");
    let seed = args.get_u64("seed", 7);
    let scale = args.get_usize("scale", if quick { 4 } else { 16 });
    let n = args.get_usize("n", if quick { 4 } else { 8 });
    let out_path = args.get_or("out", "ELASTIC_10.json");
    let profile = serve::fairness_profile(scale, n, seed);
    // Small batches keep the heavy tenant dispatching across several
    // epoch boundaries, so the repartitioner reshapes live demand
    // instead of waking up after the profile drained.
    let opts = serve::ServeOptions {
        cfg: serve::ServeCfg {
            max_batch: 4,
            ..serve::ServeCfg::default()
        },
        ..serve::ServeOptions::default()
    };
    let policy = serve::ElasticPolicy::scarce();
    println!(
        "elastic: seed {seed}, epoch {} tick(s), drain {} tick(s)/instance, \
         hot >= {} req(s)/epoch, {} instance(s) starting at {} slot(s)/class + {} channel(s)",
        policy.epoch_ticks,
        policy.drain_ticks,
        policy.hot_requests,
        opts.pool_size,
        policy.initial_slots,
        policy.initial_channels
    );
    let baseline = serve::run_profile_elastic(&profile, &opts, &policy.static_allocation());
    let elastic = serve::run_profile_elastic(&profile, &opts, &policy);
    print!("{}", report::serve_table(&elastic.report));
    let gate = report::ElasticGate::check(&elastic, &baseline);
    print!("{}", report::elastic_summary(&gate, &elastic));
    if !gate.passed() {
        eprintln!("serve: elastic gate failed");
        eprintln!("serve: refusing to write {out_path}");
        std::process::exit(1);
    }
    let json = report::elastic::to_json(&gate, &policy, &elastic, seed, quick);
    std::fs::write(&out_path, &json).unwrap_or_else(|e| panic!("cannot write `{out_path}`: {e}"));
    println!("wrote {out_path}");
}

/// One benchmark's trace workload: the graph, per-item configs for the
/// token/lane engines, and the same items as stream waves (mirrors the
/// bench suite's batch construction, including the SAXPY pipeline).
fn trace_inputs(
    which: &str,
    items: usize,
    n: usize,
    seed: u64,
) -> (
    dataflow_accel::dfg::Graph,
    Vec<sim::SimConfig>,
    Vec<sim::WaveInput>,
) {
    if which == "saxpy" {
        let g = bench_defs::saxpy::build();
        let pairs = bench_defs::saxpy::waves(items, n, seed);
        let cfgs = pairs
            .iter()
            .map(|(w, _)| {
                let mut c = sim::SimConfig::new();
                for (p, s) in w {
                    c = c.inject(p, s.clone());
                }
                c
            })
            .collect();
        let waves = pairs.into_iter().map(|(w, _)| w).collect();
        (g, cfgs, waves)
    } else {
        let bench = BenchId::from_slug(which)
            .unwrap_or_else(|| panic!("unknown benchmark `{which}`"));
        let g = bench_defs::build(bench);
        let wls = bench_defs::wave_workloads(bench, items, n, seed);
        let cfgs = wls.iter().map(|w| w.sim_config()).collect();
        let waves = wls.into_iter().map(|w| w.inject).collect();
        (g, cfgs, waves)
    }
}

/// `trace`: deterministic observability capture (OBS_9.json).
fn cmd_trace(args: &Args) {
    if args.has("serve") {
        cmd_trace_serve(args);
        return;
    }
    match args.get("bench") {
        Some(slug) => cmd_trace_bench(args, slug),
        None => panic!("trace wants --bench <slug> or --serve"),
    }
}

/// `trace --bench <slug>`: run the token, lane, and stream engines over
/// one benchmark with profiling at Full, cross-check each engine's
/// profiled firing total against an unprofiled run of the identical
/// workload, and write OBS_9.json. Any disagreement means the profiler
/// perturbed (or miscounted) execution, so the CLI refuses the
/// artifact — a trace that lies is worse than none.
fn cmd_trace_bench(args: &Args, which: &str) {
    use dataflow_accel::obs::{
        self, EngineProfile, ObsArtifact, ProfileLevel, SpanKind, TraceBuf, TraceEvent,
    };
    let n = args.get_usize("n", 8);
    let seed = args.get_u64("seed", 7);
    let items = args.get_usize("items", 8);
    let out_path = args.get_or("out", "OBS_9.json");
    let wall0 = std::time::Instant::now();
    let (g, cfgs, waves) = trace_inputs(which, items, n, seed);
    let budget = 1_000_000u64.saturating_mul(waves.len().max(1) as u64);
    let buf = TraceBuf::new(TraceBuf::DEFAULT_CAPACITY);
    let mut profiles: Vec<(String, EngineProfile)> = Vec::new();
    let mut mismatches: Vec<String> = Vec::new();

    // Token engine: one profiled TokenSim per item, merged.
    let token_unprofiled: u64 = cfgs.iter().map(|c| sim::run_token(&g, c).firings).sum();
    let mut token = EngineProfile::new("token", ProfileLevel::Full, g.n_nodes(), g.n_arcs());
    for (i, cfg) in cfgs.iter().enumerate() {
        let mut s = sim::TokenSim::new(&g, cfg);
        s.enable_profiling(ProfileLevel::Full);
        let (cycles, _) = s.run_in_place(cfg);
        if let Some(p) = s.take_profile() {
            token.merge(&p);
        }
        buf.record(TraceEvent {
            kind: SpanKind::Execute,
            tenant: TraceEvent::NO_TENANT,
            seq: i as u64,
            tick: i as u64,
            cycles,
            engine: "token",
            detail: 0,
        });
    }
    if token.total_firings != token_unprofiled {
        mismatches.push(format!(
            "token: profiled firing total {} != unprofiled {token_unprofiled}",
            token.total_firings
        ));
    }
    profiles.push(("token".to_string(), token));

    // Lane engine: the whole batch through the compiled program.
    let prog = sim::Program::compile(&g);
    buf.record(TraceEvent {
        kind: SpanKind::Compile,
        tenant: TraceEvent::NO_TENANT,
        seq: 0,
        tick: 0,
        cycles: 0,
        engine: "lanes",
        detail: prog.n_nodes() as u64,
    });
    let lanes_unprofiled: u64 = sim::run_lanes(&prog, &cfgs).iter().map(|o| o.firings).sum();
    let (lane_outs, lanes) = sim::run_lanes_profiled(&prog, &cfgs, ProfileLevel::Full);
    for (i, o) in lane_outs.iter().enumerate() {
        buf.record(TraceEvent {
            kind: SpanKind::Execute,
            tenant: TraceEvent::NO_TENANT,
            seq: i as u64,
            tick: i as u64,
            cycles: o.cycles,
            engine: "lanes",
            detail: 0,
        });
    }
    if lanes.total_firings != lanes_unprofiled {
        mismatches.push(format!(
            "lanes: profiled firing total {} != unprofiled {lanes_unprofiled}",
            lanes.total_firings
        ));
    }
    profiles.push(("lanes".to_string(), lanes));

    // Stream engine: the same items as waves through a resident session.
    let mut plain = sim::StreamSession::new(&g);
    for w in &waves {
        plain.admit(w).expect("wave admission");
    }
    plain.run(budget);
    let stream_unprofiled = plain.metrics().firings;
    let mut sess = sim::StreamSession::new(&g);
    sess.enable_profiling(ProfileLevel::Full);
    for w in &waves {
        sess.admit(w).expect("wave admission");
    }
    sess.run(budget);
    let m = sess.metrics();
    buf.record(TraceEvent {
        kind: SpanKind::Execute,
        tenant: TraceEvent::NO_TENANT,
        seq: 0,
        tick: 0,
        cycles: m.rounds,
        engine: "stream",
        detail: u64::from(m.waves_completed),
    });
    let stream = sess.take_profile().expect("stream profiling enabled");
    if stream.total_firings != stream_unprofiled {
        mismatches.push(format!(
            "stream: profiled firing total {} != unprofiled {stream_unprofiled}",
            stream.total_firings
        ));
    }
    profiles.push(("stream".to_string(), stream));

    for (label, p) in &profiles {
        print!("{}", report::hottest_nodes_table(label, p, 5));
        print!("{}", report::stall_table(label, p, 5));
    }
    let events = buf.drain_sorted();
    print!("{}", report::demotion_ledger(&events));
    if !mismatches.is_empty() {
        for msg in &mismatches {
            eprintln!("trace: {msg}");
        }
        eprintln!("trace: refusing to write {out_path}");
        std::process::exit(1);
    }
    let source = format!("bench:{which}");
    let art = ObsArtifact {
        source: &source,
        events: &events,
        profiles: &profiles,
        families: &[],
        dropped: buf.dropped(),
        wall_clock_ns: Some(wall0.elapsed().as_nanos() as u64),
    };
    std::fs::write(&out_path, obs::obs_json(&art))
        .unwrap_or_else(|e| panic!("cannot write `{out_path}`: {e}"));
    println!("wrote {out_path} ({} spans, 3 engine profiles)", events.len());
    if let Some(chrome) = args.get("chrome") {
        std::fs::write(chrome, obs::chrome_trace(&events))
            .unwrap_or_else(|e| panic!("cannot write `{chrome}`: {e}"));
        println!("wrote {chrome}");
    }
}

/// `trace --serve`: one service-tier run with the span trace attached;
/// the artifact's event stream is the same deterministic view the
/// worker-count conformance properties compare.
fn cmd_trace_serve(args: &Args) {
    use dataflow_accel::obs::{self, ObsArtifact, SpanKind, TraceBuf};
    use dataflow_accel::serve;
    use std::sync::Arc;
    let quick = args.has("quick");
    let seed = args.get_u64("seed", 7);
    let scale = args.get_usize("scale", if quick { 2 } else { 8 });
    let n = args.get_usize("n", if quick { 4 } else { 8 });
    let workers = args.get_usize("workers", 1).max(1);
    let out_path = args.get_or("out", "OBS_9.json");
    let profile = serve::standard_profile(scale, n, seed);
    let buf = Arc::new(TraceBuf::new(TraceBuf::DEFAULT_CAPACITY));
    let opts = serve::ServeOptions {
        workers,
        trace: Some(buf.clone()),
        ..serve::ServeOptions::default()
    };
    let outcome = serve::run_profile(&profile, &opts);
    let events = buf.drain_sorted();
    print!("{}", report::serve_table(&outcome.report));
    print!("{}", report::demotion_ledger(&events));
    // Accounting gate: every completed request must have an Execute span.
    let executes = events
        .iter()
        .filter(|e| matches!(e.kind, SpanKind::Execute))
        .count() as u64;
    if executes != outcome.report.global.completed {
        eprintln!(
            "trace: {executes} execute span(s) != {} completed request(s)",
            outcome.report.global.completed
        );
        eprintln!("trace: refusing to write {out_path}");
        std::process::exit(1);
    }
    // No wall-clock sidecar: the file is byte-identical at every
    // worker count (see `serve --trace`).
    let art = ObsArtifact {
        source: "serve",
        events: &events,
        profiles: &[],
        families: &[],
        dropped: buf.dropped(),
        wall_clock_ns: None,
    };
    std::fs::write(&out_path, obs::obs_json(&art))
        .unwrap_or_else(|e| panic!("cannot write `{out_path}`: {e}"));
    println!(
        "wrote {out_path} ({} spans at {workers} worker(s))",
        events.len()
    );
    if let Some(chrome) = args.get("chrome") {
        std::fs::write(chrome, obs::chrome_trace(&events))
            .unwrap_or_else(|e| panic!("cannot write `{chrome}`: {e}"));
        println!("wrote {chrome}");
    }
}

/// `bench --trace-overhead`: A/B the lane hot path with profiling off
/// (the production `run_lanes`, whose per-node profile branch is a
/// single null check) against `ProfileLevel::Full`. Outputs and firing
/// totals must be identical — `Off` changes no digests, `Full` changes
/// no results, only adds counters — and the wall-time ratio is printed
/// against the documented 2.5x bound (DESIGN.md §12). Output
/// divergence is fatal; a slow machine exceeding the bound is flagged
/// but not fatal (timing noise is not a correctness failure).
fn cmd_bench_trace_overhead(args: &Args) {
    use dataflow_accel::obs::ProfileLevel;
    let quick = args.has("quick");
    let items = args.get_usize("items", if quick { 8 } else { 64 });
    let n = args.get_usize("n", if quick { 4 } else { 16 });
    let seed = args.get_u64("seed", 7);
    let mut names: Vec<String> = BenchId::ALL.iter().map(|b| b.slug().to_string()).collect();
    names.push("saxpy".to_string());
    println!("lane-engine profiling overhead (Off vs Full): {items} items of size {n}");
    println!(
        "{:<12} {:>12} {:>12} {:>8} {:>9}",
        "benchmark", "off_ns", "full_ns", "ratio", "verdict"
    );
    let mut diverged = Vec::new();
    for name in &names {
        let (g, cfgs, _) = trace_inputs(name, items, n, seed);
        let prog = sim::Program::compile(&g);
        let reference = sim::run_lanes(&prog, &cfgs); // also warms caches
        let t0 = std::time::Instant::now();
        let off_outs = sim::run_lanes(&prog, &cfgs);
        let off_ns = (t0.elapsed().as_nanos() as u64).max(1);
        let t1 = std::time::Instant::now();
        let (full_outs, prof) = sim::run_lanes_profiled(&prog, &cfgs, ProfileLevel::Full);
        let full_ns = (t1.elapsed().as_nanos() as u64).max(1);
        let firings: u64 = reference.iter().map(|o| o.firings).sum();
        let same = reference.len() == full_outs.len()
            && reference
                .iter()
                .zip(&full_outs)
                .all(|(a, b)| a.outputs == b.outputs && a.firings == b.firings)
            && reference
                .iter()
                .zip(&off_outs)
                .all(|(a, b)| a.outputs == b.outputs)
            && prof.total_firings == firings;
        let ratio = full_ns as f64 / off_ns as f64;
        let verdict = if !same {
            diverged.push(name.clone());
            "MISMATCH"
        } else if ratio <= 2.5 {
            "ok"
        } else {
            "over"
        };
        println!("{name:<12} {off_ns:>12} {full_ns:>12} {ratio:>7.2}x {verdict:>9}");
    }
    if !diverged.is_empty() {
        eprintln!(
            "bench: profiled lane run diverged from unprofiled: {}",
            diverged.join(", ")
        );
        std::process::exit(1);
    }
    println!("documented bound: Full <= 2.5x Off on the lane hot path (DESIGN.md section 12)");
}

fn cmd_sweep(args: &Args) {
    let engine = match args.get_or("engine", "native").as_str() {
        "native" => Engine::Native,
        "xla" => Engine::Xla,
        other => panic!("unknown engine `{other}`"),
    };
    let workers = args.get_usize("workers", 4);
    let batch = args.get_usize("batch", 8);
    let requests = args.get_usize("requests", 64);
    let n = args.get_usize("n", 16);
    let which = args.get_or("bench", "all");
    let benches: Vec<BenchId> = if which == "all" {
        BenchId::ALL.to_vec()
    } else {
        vec![BenchId::from_slug(&which).expect("benchmark")]
    };

    let c = if args.has("stream") {
        Coordinator::start_streamed(workers, batch).expect("coordinator start")
    } else {
        Coordinator::start(workers, engine, Some("artifacts"), batch)
            .expect("coordinator start")
    };
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| {
            c.submit(Request {
                bench: benches[i % benches.len()],
                n,
                seed: i as u64,
            })
        })
        .collect();
    let mut ok = 0;
    for rx in rxs {
        let resp = rx.recv().expect("response");
        if resp.verified {
            ok += 1;
        }
    }
    let dt = t0.elapsed();
    println!("{}", c.metrics.summary());
    println!("{}", c.pool.summary());
    println!(
        "sweep: {requests} requests ({ok} verified) in {:.2}s = {:.1} req/s",
        dt.as_secs_f64(),
        requests as f64 / dt.as_secs_f64()
    );
    c.shutdown();
}

fn cmd_info() {
    println!("dataflow-accel — Silva et al. 2011 static dataflow architecture");
    println!("benchmarks (graph size / resources / fmax):");
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let r = estimate::estimate(&g);
        println!(
            "  {:<12} {:>3} nodes {:>3} arcs | FF {:>5} LUT {:>5} slices {:>5} | {:.1} MHz",
            b.slug(),
            g.n_nodes(),
            g.n_arcs(),
            r.ff,
            r.lut,
            r.slices,
            r.fmax_mhz
        );
    }
    match dataflow_accel::runtime::FabricRuntime::load("artifacts") {
        Ok(rt) => println!("fabric artifacts: {:?}", rt.shapes()),
        Err(e) => println!("fabric artifacts: unavailable ({e})"),
    }
}
