//! `dataflow-accel` — CLI for the static dataflow accelerator.
//!
//! ```text
//! dataflow-accel run <bench> [--n 16] [--seed 7] [--engine token|fsm|dynamic]
//! dataflow-accel compile <bench> [--emit asm|vhdl|c|resources]
//! dataflow-accel place <bench> [--shards K] [--channels N] [--check] [--reconfig]
//! dataflow-accel table1 [--fig8]
//! dataflow-accel sweep [--bench all] [--requests 64] [--n 16] [--engine native|xla]
//!                      [--workers 4] [--batch 8]
//! dataflow-accel info
//! ```

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::coordinator::{Coordinator, Engine, Request};
use dataflow_accel::fabric::{self, FabricTopology};
use dataflow_accel::util::args::Args;
use dataflow_accel::{estimate, frontend, report, sim, vhdl};

fn main() {
    let args = Args::parse(
        std::env::args().skip(1),
        &["fig8", "verbose", "check", "reconfig"],
    );
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "compile" => cmd_compile(&args),
        "place" => cmd_place(&args),
        "table1" => {
            if args.has("fig8") {
                print!("{}", report::fig8_csv());
            } else {
                print!("{}", report::table1());
            }
        }
        "sweep" => cmd_sweep(&args),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: dataflow-accel <run|compile|place|table1|sweep|info> [options]\n\
                 place: map a benchmark onto the physical fabric model \n\
                 \x20 --shards K    size the fabric to ~1/K of the graph (forces partitioning)\n\
                 \x20 --channels N  override the bus-channel pool\n\
                 \x20 --check       run sharded + whole-graph sims and compare outputs\n\
                 \x20 --reconfig    time-multiplex the shards on one fabric, report swap cost\n\
                 benchmarks: {}",
                BenchId::ALL.map(|b| b.slug()).join(" ")
            );
        }
    }
}

fn bench_arg(args: &Args) -> BenchId {
    let name = args
        .positional
        .get(1)
        .unwrap_or_else(|| panic!("missing benchmark name"));
    BenchId::from_slug(name).unwrap_or_else(|| panic!("unknown benchmark `{name}`"))
}

fn cmd_run(args: &Args) {
    let bench = bench_arg(args);
    let n = args.get_usize("n", 16);
    let seed = args.get_u64("seed", 7);
    let g = bench_defs::build(bench);
    let wl = bench_defs::workload(bench, n, seed);
    let cfg = wl.sim_config();
    let out = match args.get_or("engine", "token").as_str() {
        "token" => sim::run_token(&g, &cfg),
        "fsm" => {
            let mut cfg = cfg.clone();
            cfg.max_cycles *= 4;
            sim::run_fsm(&g, &cfg)
        }
        "dynamic" => sim::run_dynamic(&g, &cfg, 4),
        other => panic!("unknown engine `{other}`"),
    };
    println!(
        "{}: {} nodes, {} arcs | {} cycles, {} firings, quiescent={}",
        bench.slug(),
        g.n_nodes(),
        g.n_arcs(),
        out.cycles,
        out.firings,
        out.quiescent
    );
    for (port, want) in &wl.expect {
        let got = out.stream(port);
        let ok = got == want.as_slice();
        println!(
            "  {port}: {got:?} {}",
            if ok { "(verified)" } else { "(MISMATCH)" }
        );
    }
}

fn cmd_compile(args: &Args) {
    let bench = bench_arg(args);
    match args.get_or("emit", "asm").as_str() {
        "asm" => print!("{}", bench_defs::asm_source(bench)),
        "c" => print!("{}", bench_defs::c_source(bench)),
        "vhdl" => {
            // Compile the C source through the frontend, then emit VHDL —
            // the paper's full future-work chain.
            let g = frontend::compile(bench.slug(), bench_defs::c_source(bench))
                .expect("benchmark C source compiles");
            print!("{}", vhdl::generate(&g).render());
        }
        "resources" => {
            let g = bench_defs::build(bench);
            let r = estimate::estimate(&g);
            let t = estimate::estimate_trimmed(&g);
            println!(
                "{}: FF {} (trimmed {}), LUT {}, slices {}, bram {} bits, fmax {:.1} MHz",
                bench.slug(),
                r.ff,
                t.ff,
                r.lut,
                r.slices,
                r.bram_bits,
                r.fmax_mhz
            );
        }
        other => panic!("unknown --emit `{other}`"),
    }
}

fn cmd_place(args: &Args) {
    let bench = bench_arg(args);
    let g = bench_defs::build(bench);
    let mut topo = match args.get("shards") {
        Some(k) => {
            let k: usize = k.parse().unwrap_or_else(|_| panic!("--shards wants a number"));
            FabricTopology::sized_for_shards(&g, k)
        }
        None => FabricTopology::paper(),
    };
    if let Some(ch) = args.get("channels") {
        topo.channels = ch.parse().unwrap_or_else(|_| panic!("--channels wants a number"));
    }
    print!("{}", report::placement_table(&g, &topo));

    if args.has("check") || args.has("reconfig") {
        let n = args.get_usize("n", 8);
        let seed = args.get_u64("seed", 7);
        let wl = bench_defs::workload(bench, n, seed);
        let cfg = wl.sim_config();
        let whole = sim::run_token(&g, &cfg);
        match fabric::partition(&g, &topo) {
            Ok(plan) => {
                if args.has("check") {
                    let sharded = fabric::run_sharded(&plan, &cfg);
                    let ok = sharded.outputs == whole.outputs;
                    println!(
                        "check: {} shard(s), outputs {} whole-graph TokenSim",
                        plan.n_shards(),
                        if ok { "MATCH" } else { "DIFFER from" }
                    );
                }
                if args.has("reconfig") {
                    let (out, stats) = fabric::run_reconfig(&plan, &topo, &cfg);
                    let ok = out.outputs == whole.outputs;
                    println!(
                        "reconfig: {} context load(s), {} reconfig + {} active cycles, \
                         outputs {}",
                        stats.swaps,
                        stats.reconfig_cycles,
                        stats.active_cycles,
                        if ok { "MATCH" } else { "DIFFER" }
                    );
                }
            }
            Err(e) => println!("check: unpartitionable ({e})"),
        }
    }
}

fn cmd_sweep(args: &Args) {
    let engine = match args.get_or("engine", "native").as_str() {
        "native" => Engine::Native,
        "xla" => Engine::Xla,
        other => panic!("unknown engine `{other}`"),
    };
    let workers = args.get_usize("workers", 4);
    let batch = args.get_usize("batch", 8);
    let requests = args.get_usize("requests", 64);
    let n = args.get_usize("n", 16);
    let which = args.get_or("bench", "all");
    let benches: Vec<BenchId> = if which == "all" {
        BenchId::ALL.to_vec()
    } else {
        vec![BenchId::from_slug(&which).expect("benchmark")]
    };

    let c = Coordinator::start(workers, engine, Some("artifacts"), batch)
        .expect("coordinator start");
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| {
            c.submit(Request {
                bench: benches[i % benches.len()],
                n,
                seed: i as u64,
            })
        })
        .collect();
    let mut ok = 0;
    for rx in rxs {
        let resp = rx.recv().expect("response");
        if resp.verified {
            ok += 1;
        }
    }
    let dt = t0.elapsed();
    println!("{}", c.metrics.summary());
    println!("{}", c.pool.summary());
    println!(
        "sweep: {requests} requests ({ok} verified) in {:.2}s = {:.1} req/s",
        dt.as_secs_f64(),
        requests as f64 / dt.as_secs_f64()
    );
    c.shutdown();
}

fn cmd_info() {
    println!("dataflow-accel — Silva et al. 2011 static dataflow architecture");
    println!("benchmarks (graph size / resources / fmax):");
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let r = estimate::estimate(&g);
        println!(
            "  {:<12} {:>3} nodes {:>3} arcs | FF {:>5} LUT {:>5} slices {:>5} | {:.1} MHz",
            b.slug(),
            g.n_nodes(),
            g.n_arcs(),
            r.ff,
            r.lut,
            r.slices,
            r.fmax_mhz
        );
    }
    match dataflow_accel::runtime::FabricRuntime::load("artifacts") {
        Ok(rt) => println!("fabric artifacts: {:?}", rt.shapes()),
        Err(e) => println!("fabric artifacts: unavailable ({e})"),
    }
}
