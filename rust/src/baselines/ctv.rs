//! The C-to-Verilog baseline model: sequential/pipelined datapath with a
//! central register file and shared, mux-fed ALUs.

use super::spec::KernelSpec;
use crate::estimate::{op_cost, op_delay_ns, Resources, WORD_BITS};

/// Resource estimate for a CtV-compiled kernel.
///
/// Structure of the model (each term is a standard feature of sequential
/// HLS datapaths):
///
/// * register file: one 16-bit register per live variable;
/// * pipeline registers: CtV registers every live value in every schedule
///   stage of every unrolled datapath copy — the dominant FF term on
///   unrolled kernels (Pop count) and nested ones (Bubble sort);
/// * memory interface: address + data registers per array port;
/// * control: one-hot schedule FSM.
pub fn estimate(s: &KernelSpec) -> Resources {
    let w = WORD_BITS;
    let regfile_ff = w * s.vars;
    let pipe_ff = w * s.vars * s.states * s.unroll / 2;
    let mem_ff = 12 * s.arrays; // address registers (data flows through)
    let fsm_ff = s.states * s.unroll + 8;
    // A LUT-mapped multiplier is internally pipelined by CtV (2 stages of
    // 16+16 partial-product registers) — the Dot prod FF outlier.
    let mul_ff: u32 = s
        .body_ops
        .iter()
        .filter(|(op, _)| matches!(op, crate::dfg::Op::Mul))
        .map(|&(_, k)| 64 * k)
        .sum::<u32>()
        * s.unroll;
    let ff = regfile_ff + pipe_ff + mem_ff + fsm_ff + mul_ff;

    // ALUs are replicated per unrolled copy; every ALU operand comes from
    // an operand mux over the register file, every register input from a
    // writeback mux.
    let alu_lut: u32 = s
        .body_ops
        .iter()
        .map(|&(op, k)| op_cost(op).alu_lut * k)
        .sum::<u32>()
        * s.unroll;
    let mux_lut = w * s.vars * (s.states.min(4)) + w * s.arrays * 2;
    let decode_lut = 4 * s.states * s.unroll;
    let lut = alu_lut + mux_lut + decode_lut;

    // Sequential datapaths pack reasonably well; add a small routing term.
    let slices = (lut as f64 / 3.2).ceil() as u32 + (ff as f64 / 8.0).ceil() as u32;

    Resources {
        ff,
        lut,
        slices,
        bram_bits: s.arrays * 1024 * w,
        fmax_mhz: fmax(s),
    }
}

/// CtV critical path: clk→Q + operand mux tree + (chained) ALU +
/// writeback mux + setup. Chaining dependent ops into one state is what
/// drags Fibonacci and Dot prod down in Table 1.
fn fmax(s: &KernelSpec) -> f64 {
    let worst_alu = s
        .body_ops
        .iter()
        .map(|&(op, _)| op_delay_ns(op))
        .fold(0.0f64, f64::max);
    // Operand mux depth grows with the register-file width (array streams
    // are read sequentially through one port, no extra mux level).
    let sources = s.vars.max(2);
    let mux = 0.36 * (sources as f64).log2().ceil();
    // Chained ALUs in one state stack their delays plus inter-op muxing.
    let chain = worst_alu * s.chain as f64 + 0.22 * (s.chain.saturating_sub(1)) as f64;
    let control = 0.05 * s.states as f64;
    let path_ns = 1.10 + mux + chain + control;
    1000.0 / path_ns
}

/// Latency of one kernel execution of size `n`: a sequential schedule
/// pays `states` cycles per iteration (the unrolled copies overlap), and
/// nested kernels iterate n².
pub fn latency_cycles(s: &KernelSpec, n: u64) -> u64 {
    let trips = if s.nested { n * n } else { n };
    let effective_states = (s.states as u64).max(1);
    2 + trips * effective_states / s.unroll.max(1) as u64 + s.states as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::kernel_spec;
    use crate::bench_defs::BenchId;

    #[test]
    fn ff_ordering_matches_paper_shape() {
        // Paper Table 1 CtV FF: bubble 2353 > pop 1023 > dot 758 >
        // max 496 > vecsum 177 > fib 73. We require the same ordering for
        // the two extremes and bubble strictly dominant.
        let ff = |b| estimate(&kernel_spec(b)).ff;
        assert!(ff(BenchId::BubbleSort) > ff(BenchId::PopCount));
        assert!(ff(BenchId::PopCount) > ff(BenchId::DotProd));
        assert!(ff(BenchId::DotProd) > ff(BenchId::Max));
        assert!(ff(BenchId::Max) > ff(BenchId::VectorSum));
    }

    #[test]
    fn fmax_ordering_matches_paper_shape() {
        // Paper CtV Fmax: bubble 239 < dot 249 < fib 298 < pop 411 <
        // max 436 < vecsum 547. Require the two ends and monotone middle.
        let f = |b| estimate(&kernel_spec(b)).fmax_mhz;
        assert!(f(BenchId::BubbleSort) < f(BenchId::DotProd));
        assert!(f(BenchId::DotProd) < f(BenchId::Fibonacci));
        assert!(f(BenchId::Fibonacci) < f(BenchId::Max));
        assert!(f(BenchId::Max) < f(BenchId::VectorSum));
    }

    #[test]
    fn fmax_in_paper_band() {
        for b in BenchId::ALL {
            let f = estimate(&kernel_spec(b)).fmax_mhz;
            assert!((150.0..650.0).contains(&f), "{}: {f:.0} MHz", b.slug());
        }
    }

    #[test]
    fn latency_unrolling_helps() {
        let mut s = kernel_spec(BenchId::PopCount);
        let rolled = {
            s.unroll = 1;
            latency_cycles(&s, 16)
        };
        let unrolled = latency_cycles(&kernel_spec(BenchId::PopCount), 16);
        assert!(unrolled < rolled);
    }
}
