//! Models of the two comparison systems in Table 1.
//!
//! The paper compares its dataflow accelerator against two HLS flows it
//! did not publish sources for:
//!
//! * **C-to-Verilog** (c-to-verilog.com, Ben-Asher & Rotem) — a classic
//!   *sequential datapath* generator: one finite-state schedule per loop
//!   body, a central register file, shared ALUs behind operand mux trees.
//!   [`ctv`] models its resource/timing signature: FF grows with the
//!   pipelined schedule (live values × stages), LUTs are mux-dominated,
//!   and Fmax suffers from mux→ALU→mux paths and chained operations.
//! * **LALP** (Menotti & Cardoso 2010) — *aggressive loop pipelining* on
//!   a minimal counter-driven datapath: one ALU lane per loop, address
//!   generators, almost no control. [`lalp`] models its signature: the
//!   smallest FF/LUT of the three systems, mid-range Fmax.
//!
//! Both models consume a per-benchmark [`KernelSpec`] (loop structure,
//! live variables, per-iteration operations, array ports) — the same
//! abstract kernel our dataflow graphs implement — so the three columns
//! of Table 1 are generated from one benchmark description. The paper's
//! LALP column has no Pop count row (LALP's published suite lacks it);
//! [`lalp::estimate`] returns `None` there to match.

pub mod ctv;
pub mod lalp;
mod spec;

pub use spec::{kernel_spec, KernelSpec};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::BenchId;
    use crate::estimate::{estimate, estimate_trimmed};

    /// Fig. 8's headline qualitative claims, asserted across the suite.
    #[test]
    fn fig8_fmax_ours_is_fastest() {
        for b in BenchId::ALL {
            let ours = estimate(&crate::bench_defs::build(b)).fmax_mhz;
            let c = ctv::estimate(&kernel_spec(b)).fmax_mhz;
            assert!(ours > c, "{}: ours {ours:.0} ≤ CtV {c:.0}", b.slug());
            if let Some(l) = lalp::estimate(&kernel_spec(b)) {
                assert!(ours > l.fmax_mhz, "{}: ours ≤ LALP", b.slug());
            }
        }
    }

    #[test]
    fn fig8_lalp_occupies_least() {
        for b in BenchId::ALL {
            let Some(l) = lalp::estimate(&kernel_spec(b)) else {
                continue;
            };
            let c = ctv::estimate(&kernel_spec(b));
            let ours = estimate_trimmed(&crate::bench_defs::build(b));
            assert!(l.ff < c.ff, "{}: LALP FF ≥ CtV FF", b.slug());
            assert!(l.ff < ours.ff, "{}: LALP FF ≥ ours FF", b.slug());
            assert!(l.lut < c.lut, "{}: LALP LUT ≥ CtV LUT", b.slug());
            assert!(l.lut < ours.lut, "{}: LALP LUT ≥ ours LUT", b.slug());
        }
    }

    #[test]
    fn fig8_ours_ff_below_ctv_on_loop_heavy_benchmarks() {
        // The paper's FF claim ("ours < C-to-Verilog for all benchmarks")
        // holds under the control-trimmed measurement; the big sequential
        // schedules (bubble, popcount-unrolled, dot) show it strongest.
        for b in [BenchId::BubbleSort, BenchId::PopCount, BenchId::DotProd] {
            let ours = estimate_trimmed(&crate::bench_defs::build(b));
            let c = ctv::estimate(&kernel_spec(b));
            assert!(ours.ff < c.ff, "{}: ours {} ≥ CtV {}", b.slug(), ours.ff, c.ff);
        }
    }

    #[test]
    fn fig8_slices_ours_highest_for_most() {
        // "the Acceleration Algorithms occupy more slices than the
        // C-to-Verilog and the LALP system" — routing-dominated fabric.
        let mut ours_higher = 0;
        let mut total = 0;
        for b in BenchId::ALL {
            let ours = estimate(&crate::bench_defs::build(b));
            let c = ctv::estimate(&kernel_spec(b));
            total += 1;
            if ours.slices > c.slices {
                ours_higher += 1;
            }
        }
        assert!(
            ours_higher * 2 > total,
            "ours wins slices on only {ours_higher}/{total}"
        );
    }

    #[test]
    fn ctv_latency_scales_with_schedule() {
        let fib = ctv::latency_cycles(&kernel_spec(BenchId::Fibonacci), 32);
        let bub = ctv::latency_cycles(&kernel_spec(BenchId::BubbleSort), 32);
        // n² trips dominate even after the 8× unrolled inner chain.
        assert!(bub > fib * 4, "nested loop must dominate: {bub} vs {fib}");
    }

    #[test]
    fn lalp_latency_is_ii1_after_fill() {
        let s = kernel_spec(BenchId::VectorSum);
        let l64 = lalp::latency_cycles(&s, 64);
        let l128 = lalp::latency_cycles(&s, 128);
        // Slope 1 element/cycle.
        assert_eq!(l128 - l64, 64);
    }
}
