//! Abstract kernel descriptions shared by the baseline models.

use crate::bench_defs::BenchId;
use crate::dfg::Op;

/// What an HLS compiler sees of a benchmark: loop structure, live scalar
/// variables, the per-iteration operation list, and memory ports.
#[derive(Debug, Clone)]
pub struct KernelSpec {
    pub bench: BenchId,
    /// Live scalar variables across the loop body.
    pub vars: u32,
    /// Operations executed per (innermost) iteration.
    pub body_ops: Vec<(Op, u32)>,
    /// Schedule states per iteration in a sequential (CtV-style) schedule.
    pub states: u32,
    /// Longest chain of dependent ops scheduled in one state (hurts Fmax).
    pub chain: u32,
    /// Arrays / streams touched (each costs address generation + a port).
    pub arrays: u32,
    /// Datapath replication the HLS flow applies (CtV unrolls Pop count's
    /// fixed 16-bit loop and Bubble sort's inner compare-exchange chain).
    pub unroll: u32,
    /// True for doubly-nested iteration spaces (n² trip count).
    pub nested: bool,
}

/// Per-benchmark kernel description. The numbers are what the respective
/// C sources (bench_defs::c_source) imply: variable counts and op lists
/// are read off the source; `unroll` follows each tool's documented
/// behaviour on fixed-bound inner loops.
pub fn kernel_spec(b: BenchId) -> KernelSpec {
    match b {
        BenchId::Fibonacci => KernelSpec {
            bench: b,
            vars: 4, // first, second, tmp, i
            body_ops: vec![(Op::Add, 2)],
            states: 2,
            chain: 2, // tmp = first+second then i+1 chained with copy-back
            arrays: 0,
            unroll: 1,
            nested: false,
        },
        BenchId::Max => KernelSpec {
            bench: b,
            vars: 3, // m, v, i
            body_ops: vec![(Op::IfGt, 1), (Op::Add, 1)],
            states: 3, // load, compare, select/writeback
            chain: 1,
            arrays: 1,
            unroll: 1,
            nested: false,
        },
        BenchId::DotProd => KernelSpec {
            bench: b,
            vars: 3, // acc, i, prod
            body_ops: vec![(Op::Mul, 1), (Op::Add, 2)],
            states: 3, // load, mul, acc
            chain: 2,  // mul feeding add
            arrays: 2,
            unroll: 1,
            nested: false,
        },
        BenchId::VectorSum => KernelSpec {
            bench: b,
            vars: 2, // i and the sum temporary
            body_ops: vec![(Op::Add, 2)],
            states: 2,
            chain: 1,
            arrays: 3,
            unroll: 1,
            nested: false,
        },
        BenchId::BubbleSort => KernelSpec {
            bench: b,
            vars: 5, // i, j, a[j], a[j+1], tmp
            body_ops: vec![(Op::IfGt, 1), (Op::Add, 2)],
            states: 4, // read, read, cmp, writeback
            chain: 2,
            arrays: 1,
            // CtV pipelines/unrolls the inner compare-exchange chain.
            unroll: 8,
            nested: true,
        },
        BenchId::PopCount => KernelSpec {
            bench: b,
            vars: 3, // w, cnt, bit
            body_ops: vec![(Op::And, 1), (Op::Shr, 1), (Op::Add, 2)],
            states: 2,
            chain: 2,
            arrays: 0,
            // The 16-bit width is a compile-time constant: CtV fully
            // unrolls the bit loop.
            unroll: 16,
            nested: false,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_exist_for_all_benchmarks() {
        for b in BenchId::ALL {
            let s = kernel_spec(b);
            assert!(s.vars > 0);
            assert!(!s.body_ops.is_empty());
            assert!(s.states > 0);
            assert!(s.unroll >= 1);
        }
    }

    #[test]
    fn only_bubble_is_nested() {
        for b in BenchId::ALL {
            assert_eq!(kernel_spec(b).nested, b == BenchId::BubbleSort);
        }
    }
}
