//! The LALP baseline model: aggressive loop pipelining on a minimal
//! counter-driven datapath (Menotti & Cardoso 2010).

use super::spec::KernelSpec;
use crate::bench_defs::BenchId;
use crate::estimate::{op_cost, op_delay_ns, Resources, WORD_BITS};

/// Resource estimate for a LALP-compiled kernel, or `None` where the
/// paper's Table 1 has no LALP entry (Pop count).
///
/// LALP instantiates exactly one datapath lane per loop: the loop
/// counter, address generators for each array, one instance of each body
/// operation, and the II=1 pipeline registers between them — no register
/// file, no operand muxes, no schedule FSM. That is why its FF/LUT counts
/// in Table 1 are the smallest of the three systems.
pub fn estimate(s: &KernelSpec) -> Option<Resources> {
    if s.bench == BenchId::PopCount {
        return None; // not in LALP's published suite / the paper's table
    }
    let w = WORD_BITS;
    let depth: u32 = s.body_ops.iter().map(|&(_, k)| k).sum::<u32>().max(1);
    let counters_ff = 12 * if s.nested { 2 } else { 1 };
    let addrgen_ff = 12 * s.arrays;
    let pipe_ff = w * depth; // one pipeline register per stage
    let ff = counters_ff + addrgen_ff + pipe_ff + 6;

    let alu_lut: u32 = s
        .body_ops
        .iter()
        .map(|&(op, k)| op_cost(op).alu_lut * k)
        .sum();
    let addr_lut = 10 * s.arrays + 12 * if s.nested { 2 } else { 1 };
    let lut = alu_lut + addr_lut + 8;

    let slices = (lut as f64 / 3.5).ceil() as u32 + (ff as f64 / 8.0).ceil() as u32;

    Some(Resources {
        ff,
        lut,
        slices,
        bram_bits: s.arrays * 1024 * w,
        fmax_mhz: fmax(s),
    })
}

/// LALP critical path: one pipelined ALU stage plus the loop-carried
/// feedback mux. Mid-range: faster than CtV's mux trees, slower than the
/// fully registered dataflow fabric.
fn fmax(s: &KernelSpec) -> f64 {
    let worst_alu = s
        .body_ops
        .iter()
        .map(|&(op, _)| op_delay_ns(op))
        .fold(0.0f64, f64::max);
    // Loop-carried dependences (accumulators, swaps) add a feedback mux;
    // pure streaming kernels run near the fabric limit.
    let feedback = if s.chain > 1 { 0.45 } else { 0.12 };
    let path_ns = 1.30 + worst_alu + feedback + 0.04 * s.arrays as f64;
    1000.0 / path_ns
}

/// Latency: II=1 after pipeline fill; nested kernels iterate n².
pub fn latency_cycles(s: &KernelSpec, n: u64) -> u64 {
    let depth: u64 = s.body_ops.iter().map(|&(_, k)| k as u64).sum::<u64>().max(1);
    let trips = if s.nested { n * n } else { n };
    depth + trips
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::kernel_spec;

    #[test]
    fn popcount_has_no_lalp_row() {
        assert!(estimate(&kernel_spec(BenchId::PopCount)).is_none());
        for b in BenchId::ALL {
            if b != BenchId::PopCount {
                assert!(estimate(&kernel_spec(b)).is_some(), "{}", b.slug());
            }
        }
    }

    #[test]
    fn ff_is_paper_scale() {
        // Paper LALP FF: max 50, dot 97, fib 104, bubble 219, vecsum 350.
        // Require the right order of magnitude (tens to few hundreds).
        for b in BenchId::ALL {
            if let Some(r) = estimate(&kernel_spec(b)) {
                assert!((20..600).contains(&r.ff), "{}: {}", b.slug(), r.ff);
            }
        }
    }

    #[test]
    fn fmax_mid_range() {
        // Paper LALP Fmax: 213–505 MHz.
        for b in BenchId::ALL {
            if let Some(r) = estimate(&kernel_spec(b)) {
                assert!(
                    (180.0..600.0).contains(&r.fmax_mhz),
                    "{}: {:.0}",
                    b.slug(),
                    r.fmax_mhz
                );
            }
        }
    }

    #[test]
    fn accumulator_kernels_clock_lower() {
        // Dot prod (loop-carried accumulate through a multiplier) must be
        // slower than streaming Vector sum — the paper shows 213 vs 504.
        let dot = estimate(&kernel_spec(BenchId::DotProd)).unwrap();
        let vs = estimate(&kernel_spec(BenchId::VectorSum)).unwrap();
        assert!(dot.fmax_mhz < vs.fmax_mhz);
    }
}
