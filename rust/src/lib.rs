#![cfg_attr(feature = "simd", feature(portable_simd))]
//! # dataflow-accel
//!
//! A production-grade reproduction of *"Accelerating Algorithms using a
//! Dataflow Graph in a Reconfigurable System"* (Silva, Silva, Lopes &
//! da Silva, 2011).
//!
//! The paper prototypes a **static dataflow architecture** on an FPGA:
//! fine-grain operators (add/sub/merge/branch/...) connected by 16-bit data
//! buses with a 2-wire `str`/`ack` handshake, assembled from a tiny
//! dataflow-assembler language into a VHDL netlist, and evaluated on six
//! benchmarks against the C-to-Verilog and LALP HLS systems (Table 1 /
//! Fig. 8 of the paper).
//!
//! This crate rebuilds the whole system in software:
//!
//! * [`dfg`] — the dataflow-graph IR (operators, arcs, validation).
//! * [`asm`] — the paper's dataflow assembler language (Listing 1 syntax).
//! * [`frontend`] — the paper's named future work: a mini-C compiler that
//!   lowers a C subset to static dataflow graphs.
//! * [`opt`] — the DFG optimizer: a fixed-point pass pipeline (constant
//!   folding, copy-chain elision, CSE, dead-node elimination, strength
//!   reduction) with an [`opt::OptLevel`] knob; lowered graphs run
//!   through it by default and the serve tier caches optimized graphs
//!   keyed by pre-optimization fingerprint + level.
//! * [`sim`] — cycle-accurate simulation of the paper's operator FSMs
//!   (Figs. 5/6) and handshake protocol (Fig. 3), plus a fast token engine,
//!   a dynamic (tagged-token) extension, the wave-pipelined streaming tier,
//!   and the lane tier (compiled programs + 64-wide lockstep batch
//!   execution, `sim::compiled` / `sim::lanes`).
//! * [`vhdl`] — the VHDL backend the paper's assembler targeted.
//! * [`estimate`] — structural FF/LUT/slice/Fmax models replacing the
//!   Xilinx ISE synthesis flow we do not have.
//! * [`fabric`] — the *physical* fabric layer: finite per-class operator
//!   slot pools and bounded bus channels ([`fabric::FabricTopology`]), a
//!   placer, a min-cut partitioner for oversized graphs, a sharded
//!   executor (multi-fabric, cut arcs forwarded over inter-fabric
//!   channels), and a time-multiplexing reconfiguration scheduler. The
//!   CLI's `place` subcommand and the coordinator's fabric pool sit on
//!   top of this.
//! * [`baselines`] — resource/latency models of the two comparison systems
//!   (C-to-Verilog and LALP).
//! * [`bench_defs`] — the six paper benchmarks (C source, assembler source,
//!   programmatic builders, software references).
//! * [`runtime`] + [`coordinator`] — the acceleration path: batched fabric
//!   simulation through AOT-compiled XLA artifacts (JAX/Pallas, loaded over
//!   PJRT; Python never runs at simulation time).
//! * [`par`] — the std-only work-stealing executor (per-worker deques +
//!   global injector, scoped workers) that the lane, shard, stream, and
//!   serve tiers use to spread independent chunks/shards/batches across
//!   cores with byte-identical results at any worker count.
//! * [`obs`] — deterministic observability: typed trace spans in virtual
//!   time (byte-identical across worker counts), per-node stall
//!   attribution behind a zero-cost-when-off [`obs::ProfileLevel`], a
//!   unified counter registry, Chrome-trace / `OBS_9.json` export, and
//!   the chaos-path flight recorder.
//! * [`serve`] — the multi-tenant service tier: warm-state session cache
//!   keyed by [`dfg::Graph::fingerprint`], admission scheduler
//!   (quotas, explicit shedding, weighted-fair picking, deadline-aware
//!   batch formation, per-batch engine selection), deterministic load
//!   generator, and per-tenant latency/shed/cache statistics.
//! * [`report`] — Table 1 / Fig. 8 renderers.
//!
//! See `DESIGN.md` for the paper→module map and `EXPERIMENTS.md` for
//! paper-vs-measured numbers.

pub mod asm;
pub mod util;
pub mod baselines;
pub mod bench_defs;
pub mod coordinator;
pub mod dfg;
pub mod estimate;
pub mod fabric;
pub mod frontend;
pub mod obs;
pub mod opt;
pub mod par;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod vhdl;

pub use dfg::{Arc, ArcId, Graph, Node, NodeId, Op};
pub use fabric::FabricTopology;
pub use opt::{optimize, OptLevel, OptReport};
pub use sim::{FsmSim, SimConfig, SimOutcome, StreamSession, TokenSim};
