//! Vector sum — elementwise z[i] = x[i] + y[i] over two streams.
//!
//! The adder free-runs on the streams (pure pipeline); a counter loop —
//! the left half of the paper's Fig. 7 — counts elements and raises the
//! `pf` (loop-finished) token, which is how the paper's designs signal
//! completion to the host.

use crate::dfg::{build_loop, Graph, GraphBuilder, Op, Word};

pub const C_SOURCE: &str = "\
in int n;
in stream x;
in stream y;
out stream z;
int i = 0;
while (i < n) {
    emit(z, next(x) + next(y));
    i = i + 1;
}
";

/// Elementwise wrapping sum.
pub fn reference(xs: &[Word], ys: &[Word]) -> Vec<Word> {
    xs.iter()
        .zip(ys)
        .map(|(&a, &b)| a.wrapping_add(b))
        .collect()
}

/// Ports: `n`, streams `x`/`y` in; stream `z` and `pf` out.
pub fn build() -> Graph {
    let mut b = GraphBuilder::new("vector_sum");
    let n = b.input_port("n");
    let x = b.input_port("x");
    let y = b.input_port("y");
    let z = b.output_port("z");
    let i0 = b.constant(0);
    let one0 = b.constant(1);

    // The elementwise datapath: a single streaming adder.
    b.node(Op::Add, &[x, y], &[z]);

    // The counter loop (Fig. 7 left half): emits `pf` = n when done.
    let exits = build_loop(
        &mut b,
        &[i0, n, one0],
        &[0, 1],
        |b, c| b.op2(Op::IfLt, c[0], c[1]),
        |b, g| {
            let (one_use, one_back) = b.copy(g[2]);
            let i_next = b.op2(Op::Add, g[0], one_use);
            vec![i_next, g[1], one_back]
        },
    );
    b.rename_arc(exits[0], "pf");
    b.finish().expect("vecsum graph is structurally valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run_token, SimConfig};

    #[test]
    fn sums_elementwise() {
        let g = build();
        let xs = vec![1, 2, 3];
        let ys = vec![10, 20, 30];
        let cfg = SimConfig::new()
            .inject("n", vec![3])
            .inject("x", xs.clone())
            .inject("y", ys.clone());
        let out = run_token(&g, &cfg);
        assert_eq!(out.stream("z"), reference(&xs, &ys).as_slice());
        assert_eq!(out.last("pf"), Some(3));
    }

    #[test]
    fn empty_input() {
        let g = build();
        let cfg = SimConfig::new().inject("n", vec![0]);
        let out = run_token(&g, &cfg);
        assert_eq!(out.stream("z"), &[] as &[Word]);
        assert_eq!(out.last("pf"), Some(0));
    }

    #[test]
    fn long_stream_pipeline() {
        let g = build();
        let xs: Vec<Word> = (0..200).collect();
        let ys: Vec<Word> = (0..200).map(|v| v * 2).collect();
        let cfg = SimConfig::new()
            .inject("n", vec![200])
            .inject("x", xs.clone())
            .inject("y", ys.clone())
            .max_cycles(2_000_000);
        let out = run_token(&g, &cfg);
        assert_eq!(out.stream("z"), reference(&xs, &ys).as_slice());
    }
}
