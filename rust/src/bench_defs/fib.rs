//! Fibonacci — the paper's worked example (Algorithm 1, Fig. 7,
//! Listing 1).
//!
//! Loop variables: `i`, `n`, `one`, `first`, `second`. The constant `1`
//! circulates as a loop variable because a dataflow constant source fires
//! only once (§3.2) — this is why the paper's graph needs ~20 operators.

use crate::dfg::{build_loop, Graph, GraphBuilder, Op, Word};

/// Mini-C source for the frontend (same algorithm as the paper's
/// Algorithm 1, with the loop counted `i < n`).
pub const C_SOURCE: &str = "\
in int n;
out int fibo;
int first = 0;
int second = 1;
int i = 0;
while (i < n) {
    int tmp = first + second;
    first = second;
    second = tmp;
    i = i + 1;
}
fibo = first;
";

/// fib(0)=0, fib(1)=1, …, with 16-bit wrap-around.
pub fn reference(n: Word) -> Word {
    let (mut f, mut s) = (0i16, 1i16);
    for _ in 0..n.max(0) {
        let t = f.wrapping_add(s);
        f = s;
        s = t;
    }
    f
}

/// The hand-built dataflow graph in the paper's style.
///
/// Ports: `n` in; `fibo` (the result) and `pf` (final loop index) out.
pub fn build() -> Graph {
    let mut b = GraphBuilder::new("fibonacci");
    let n = b.input_port("n");
    let i0 = b.constant(0);
    let one0 = b.constant(1);
    let first0 = b.constant(0);
    let second0 = b.constant(1);

    // vars: [i, n, one, first, second]
    let exits = build_loop(
        &mut b,
        &[i0, n, one0, first0, second0],
        &[0, 1],
        |b, c| b.op2(Op::IfLt, c[0], c[1]),
        |b, g| {
            // tmp = first + second; first' = second; second' = tmp
            let (second_use, second_to_first) = b.copy(g[4]);
            let tmp = b.op2(Op::Add, g[3], second_use);
            // i' = i + 1 (the `one` token is copied: use + recirculate)
            let (one_use, one_back) = b.copy(g[2]);
            let i_next = b.op2(Op::Add, g[0], one_use);
            vec![i_next, g[1], one_back, second_to_first, tmp]
        },
    );
    b.rename_arc(exits[3], "fibo");
    b.rename_arc(exits[0], "pf");
    b.finish().expect("fibonacci graph is structurally valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run_token, SimConfig};

    #[test]
    fn reference_sequence() {
        let want = [0, 1, 1, 2, 3, 5, 8, 13, 21, 34];
        for (n, &w) in want.iter().enumerate() {
            assert_eq!(reference(n as Word), w, "fib({n})");
        }
    }

    #[test]
    fn graph_matches_reference() {
        let g = build();
        for n in 0..15 {
            let cfg = SimConfig::new().inject("n", vec![n]);
            let out = run_token(&g, &cfg);
            assert_eq!(out.last("fibo"), Some(reference(n)), "fib({n})");
            assert_eq!(out.last("pf"), Some(n), "pf for n={n}");
            assert!(out.quiescent);
        }
    }

    #[test]
    fn graph_size_is_paper_scale() {
        // Listing 1 has 20 operator statements; the schema-built graph
        // should land in the same ballpark (the paper's graph and ours
        // make slightly different copy-tree choices).
        let g = build();
        assert!(
            (15..=28).contains(&g.n_nodes()),
            "unexpected node count {}",
            g.n_nodes()
        );
    }

    #[test]
    fn wraps_at_16_bits() {
        // fib(24) = 46368 > i16::MAX — must wrap, not panic.
        let g = build();
        let cfg = SimConfig::new().inject("n", vec![24]).max_cycles(2_000_000);
        let out = run_token(&g, &cfg);
        assert_eq!(out.last("fibo"), Some(reference(24)));
    }
}
