//! Max vector — running maximum over a stream of `n` elements.
//!
//! The compare-and-select idiom is the paper's `gtdecider` + `dmerge`
//! pair: the decider produces the control token, the deterministic merge
//! picks the winner (§3.2 items 3 and 5).

use crate::dfg::{build_loop, Graph, GraphBuilder, Op, Word};

pub const C_SOURCE: &str = "\
in int n;
in stream x;
out int max;
int m = -32768;
int i = 0;
while (i < n) {
    int v = next(x);
    if (v > m) {
        m = v;
    }
    i = i + 1;
}
max = m;
";

/// Running maximum (identity −32768 on the empty stream).
pub fn reference(xs: &[Word]) -> Word {
    xs.iter().copied().fold(i16::MIN, Word::max)
}

/// Ports: `n`, stream `x` in; `max` out.
pub fn build() -> Graph {
    let mut b = GraphBuilder::new("max_vector");
    let n = b.input_port("n");
    let x = b.input_port("x");
    let i0 = b.constant(0);
    let one0 = b.constant(1);
    let m0 = b.constant(i16::MIN);

    // vars: [i, n, one, m]
    let exits = build_loop(
        &mut b,
        &[i0, n, one0, m0],
        &[0, 1],
        |b, c| b.op2(Op::IfLt, c[0], c[1]),
        |b, g| {
            // v = next(x); m' = v > m ? v : m.
            //
            // Conditional select is the branch/ndmerge idiom: both
            // candidates are *routed* (winner side / loser side) so every
            // token is consumed every iteration. A dmerge-based select
            // would strand the unselected token on its arc and deadlock
            // the copy tree on the next iteration.
            let (v_cmp, v_data) = b.copy(x);
            let (m_cmp, m_data) = b.copy(g[3]);
            let c = b.op2(Op::IfGt, v_cmp, m_cmp);
            let (c_v, c_m) = b.copy(c);
            let bv = b.node(Op::Branch, &[c_v, v_data], &[]);
            let (v_win, _v_lose) = (b.out_arc(bv, 0), b.out_arc(bv, 1));
            let bm = b.node(Op::Branch, &[c_m, m_data], &[]);
            let (_m_lose, m_win) = (b.out_arc(bm, 0), b.out_arc(bm, 1));
            // Exactly one of the two winner arcs carries a token.
            let mn = b.node(Op::NdMerge, &[v_win, m_win], &[]);
            let m_next = b.out_arc(mn, 0);
            // Losers drain to anonymous output ports (hardware: a sink).
            let (one_use, one_back) = b.copy(g[2]);
            let i_next = b.op2(Op::Add, g[0], one_use);
            vec![i_next, g[1], one_back, m_next]
        },
    );
    b.rename_arc(exits[3], "max");
    b.finish().expect("max graph is structurally valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run_token, SimConfig};

    #[test]
    fn finds_maximum() {
        let g = build();
        let xs = vec![3, -5, 42, 7, 42, -1000, 12];
        let cfg = SimConfig::new()
            .inject("n", vec![xs.len() as Word])
            .inject("x", xs.clone());
        let out = run_token(&g, &cfg);
        assert_eq!(out.last("max"), Some(reference(&xs)));
    }

    #[test]
    fn empty_stream_yields_identity() {
        let g = build();
        let cfg = SimConfig::new().inject("n", vec![0]);
        let out = run_token(&g, &cfg);
        assert_eq!(out.last("max"), Some(i16::MIN));
    }

    #[test]
    fn consumes_exactly_n_elements() {
        // Extra stream tokens must be left untouched (count-controlled
        // consumption).
        let g = build();
        let cfg = SimConfig::new()
            .inject("n", vec![3])
            .inject("x", vec![5, 9, 2, 777, 888]);
        let out = run_token(&g, &cfg);
        assert_eq!(out.last("max"), Some(9));
        assert!(!out.quiescent); // leftover stream tokens keep it non-quiescent
    }

    #[test]
    fn single_element() {
        let g = build();
        let cfg = SimConfig::new().inject("n", vec![1]).inject("x", vec![-7]);
        assert_eq!(run_token(&g, &cfg).last("max"), Some(-7));
    }
}
