//! SAXPY — z[i] = a[i]·x[i] + y[i], a pure elementwise pipeline.
//!
//! Not one of the paper's six benchmarks: the six are all loop-schema
//! graphs whose `ndmerge` back-edges force the streaming tier into
//! serialized wave admission. SAXPY is the canonical *pipelineable*
//! workload — unit-rate operators, no cycles — so successive waves
//! overlap inside the fabric (Fig. 1c back-to-back pipelining) and the
//! streamed-vs-run-to-completion throughput gap the paper's elastic
//! pipeline promises is actually measurable. The throughput report and
//! the conformance harness both use it.

use crate::dfg::{Graph, GraphBuilder, Op, Word};
use crate::sim::WaveInput;
use crate::util::Rng;
use std::collections::BTreeMap;

pub const C_SOURCE: &str = "\
in stream a;
in stream x;
in stream y;
out stream z;
while (1) {
    emit(z, next(a) * next(x) + next(y));
}
";

/// Elementwise wrapping a·x + y.
pub fn reference(a: &[Word], x: &[Word], y: &[Word]) -> Vec<Word> {
    a.iter()
        .zip(x)
        .zip(y)
        .map(|((&a, &x), &y)| a.wrapping_mul(x).wrapping_add(y))
        .collect()
}

/// Ports: streams `a`/`x`/`y` in, stream `z` out. A FIFO stage between
/// the multiplier and the adder deepens the pipeline (more waves in
/// flight at once).
pub fn build() -> Graph {
    let mut b = GraphBuilder::new("saxpy");
    let a = b.input_port("a");
    let x = b.input_port("x");
    let y = b.input_port("y");
    let z = b.output_port("z");
    let prod = b.op2(Op::Mul, a, x);
    let f = b.node(Op::Fifo(4), &[prod], &[]);
    let staged = b.out_arc(f, 0);
    b.node(Op::Add, &[staged, y], &[z]);
    b.finish().expect("saxpy graph is structurally valid")
}

/// A deterministic wave (one independent input set of `n` elements per
/// port) plus its expected `z` stream.
pub fn wave(n: usize, seed: u64) -> (WaveInput, Vec<Word>) {
    let mut rng = Rng::new(seed ^ 0x5A_BEEF);
    let a = rng.words(n.max(1), -50, 50);
    let x = rng.words(n.max(1), -50, 50);
    let y = rng.words(n.max(1), -500, 500);
    let expect = reference(&a, &x, &y);
    (
        BTreeMap::from([
            ("a".to_string(), a),
            ("x".to_string(), x),
            ("y".to_string(), y),
        ]),
        expect,
    )
}

/// `count` successive independent waves of size `n` (wave `i` derives
/// from `seed + i`), paired with their expected `z` streams — the
/// SAXPY analogue of [`super::wave_workloads`], used by the perf
/// harness and the lane conformance tests.
pub fn waves(count: usize, n: usize, seed: u64) -> Vec<(WaveInput, Vec<Word>)> {
    (0..count)
        .map(|i| wave(n, seed.wrapping_add(i as u64)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{overlap_safe, run_stream, run_token, SimConfig};

    #[test]
    fn saxpy_is_overlap_safe_and_correct() {
        let g = build();
        assert!(overlap_safe(&g));
        let (w, expect) = wave(6, 3);
        let mut cfg = SimConfig::new();
        for (p, s) in &w {
            cfg = cfg.inject(p, s.clone());
        }
        let out = run_token(&g, &cfg);
        assert_eq!(out.stream("z"), expect.as_slice());
        assert!(out.quiescent);
    }

    #[test]
    fn streamed_waves_verify_against_reference() {
        let g = build();
        let pairs: Vec<_> = (0..6).map(|s| wave(4, s)).collect();
        let waves: Vec<WaveInput> = pairs.iter().map(|(w, _)| w.clone()).collect();
        let (outs, m) = run_stream(&g, &waves, 100_000);
        assert_eq!(m.waves_completed, 6);
        for (i, (_, expect)) in pairs.iter().enumerate() {
            assert_eq!(outs[i].stream("z"), expect.as_slice(), "wave {i}");
        }
    }
}
