//! The six paper benchmarks (§4): Fibonacci, Max, Dot prod, Vector sum,
//! Bubble sort, Pop count.
//!
//! Each benchmark carries four synchronized representations:
//!
//! 1. a **mini-C source** (`c_source`) — compiled by [`crate::frontend`],
//! 2. an **assembler source** (`asm_source`) — printed from the built
//!    graph, i.e. the artifact class the paper's Listing 1 shows,
//! 3. a **programmatic builder** (`build`) — the hand-crafted graph in the
//!    paper's style (Fig. 7), via the canonical loop schema,
//! 4. a **software reference** (`reference` in each submodule) — plain
//!    Rust with the same 16-bit wrap-around semantics, the oracle.
//!
//! [`workload`] generates deterministic pseudo-random inputs of a given
//! size so tests, benches and the coordinator all agree on what "run Dot
//! prod with n=64, seed=7" means.

pub mod bubble;
pub mod dotprod;
pub mod fib;
pub mod max;
pub mod popcount;
pub mod saxpy;
pub mod vecsum;

use crate::dfg::{Graph, Word};
use crate::sim::SimConfig;
use crate::util::Rng;
use std::collections::BTreeMap;

/// Identifies one of the paper's benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BenchId {
    BubbleSort,
    DotProd,
    Fibonacci,
    Max,
    PopCount,
    VectorSum,
}

impl BenchId {
    /// Table-1 row order.
    pub const ALL: [BenchId; 6] = [
        BenchId::BubbleSort,
        BenchId::DotProd,
        BenchId::Fibonacci,
        BenchId::Max,
        BenchId::PopCount,
        BenchId::VectorSum,
    ];

    /// The paper's display name (Table 1 row label).
    pub fn paper_name(self) -> &'static str {
        match self {
            BenchId::BubbleSort => "Buble Sort", // sic — the paper's spelling
            BenchId::DotProd => "Dot prod",
            BenchId::Fibonacci => "Fibonacci",
            BenchId::Max => "Max vector",
            BenchId::PopCount => "Pop count",
            BenchId::VectorSum => "Vector sum",
        }
    }

    pub fn slug(self) -> &'static str {
        match self {
            BenchId::BubbleSort => "bubble_sort",
            BenchId::DotProd => "dot_prod",
            BenchId::Fibonacci => "fibonacci",
            BenchId::Max => "max_vector",
            BenchId::PopCount => "pop_count",
            BenchId::VectorSum => "vector_sum",
        }
    }

    pub fn from_slug(s: &str) -> Option<BenchId> {
        BenchId::ALL.iter().copied().find(|b| b.slug() == s)
    }
}

/// A fully-specified benchmark instance: inputs plus expected outputs.
#[derive(Debug, Clone)]
pub struct Workload {
    pub bench: BenchId,
    /// Injection streams per input port.
    pub inject: BTreeMap<String, Vec<Word>>,
    /// Expected tokens per output port the benchmark defines.
    pub expect: BTreeMap<String, Vec<Word>>,
    /// A generous round budget for the fast engine.
    pub max_cycles: u64,
}

impl Workload {
    pub fn sim_config(&self) -> SimConfig {
        let mut cfg = SimConfig::new().max_cycles(self.max_cycles);
        for (p, s) in &self.inject {
            cfg = cfg.inject(p, s.clone());
        }
        cfg
    }
}

/// Build the dataflow graph for a benchmark.
pub fn build(bench: BenchId) -> Graph {
    match bench {
        BenchId::Fibonacci => fib::build(),
        BenchId::Max => max::build(),
        BenchId::DotProd => dotprod::build(),
        BenchId::VectorSum => vecsum::build(),
        BenchId::BubbleSort => bubble::build(),
        BenchId::PopCount => popcount::build(),
    }
}

/// The benchmark's mini-C source (compiled by `crate::frontend`).
pub fn c_source(bench: BenchId) -> &'static str {
    match bench {
        BenchId::Fibonacci => fib::C_SOURCE,
        BenchId::Max => max::C_SOURCE,
        BenchId::DotProd => dotprod::C_SOURCE,
        BenchId::VectorSum => vecsum::C_SOURCE,
        BenchId::BubbleSort => bubble::C_SOURCE,
        BenchId::PopCount => popcount::C_SOURCE,
    }
}

/// The benchmark's assembler source (printed from the built graph — the
/// same artifact class as the paper's Listing 1).
pub fn asm_source(bench: BenchId) -> String {
    crate::asm::print(&build(bench))
}

/// Deterministic workload of size `n` for a benchmark.
pub fn workload(bench: BenchId, n: usize, seed: u64) -> Workload {
    let mut rng = Rng::new(seed ^ ((bench as u64) << 32));
    match bench {
        BenchId::Fibonacci => {
            let arg = (n as Word).min(20);
            Workload {
                bench,
                inject: BTreeMap::from([("n".into(), vec![arg])]),
                expect: BTreeMap::from([("fibo".into(), vec![fib::reference(arg)])]),
                max_cycles: 4_000 * (arg as u64 + 2),
            }
        }
        BenchId::Max => {
            let xs = rng.words(n, -1000, 1000);
            let expect = max::reference(&xs);
            Workload {
                bench,
                inject: BTreeMap::from([
                    ("n".into(), vec![xs.len() as Word]),
                    ("x".into(), xs),
                ]),
                expect: BTreeMap::from([("max".into(), vec![expect])]),
                max_cycles: 4_000 * (n as u64 + 2),
            }
        }
        BenchId::DotProd => {
            let xs = rng.words(n, -100, 100);
            let ys = rng.words(n, -100, 100);
            let expect = dotprod::reference(&xs, &ys);
            Workload {
                bench,
                inject: BTreeMap::from([
                    ("n".into(), vec![xs.len() as Word]),
                    ("x".into(), xs),
                    ("y".into(), ys),
                ]),
                expect: BTreeMap::from([("dot".into(), vec![expect])]),
                max_cycles: 4_000 * (n as u64 + 2),
            }
        }
        BenchId::VectorSum => {
            let xs = rng.words(n, -1000, 1000);
            let ys = rng.words(n, -1000, 1000);
            let expect = vecsum::reference(&xs, &ys);
            Workload {
                bench,
                inject: BTreeMap::from([
                    ("n".into(), vec![xs.len() as Word]),
                    ("x".into(), xs),
                    ("y".into(), ys),
                ]),
                expect: BTreeMap::from([("z".into(), expect)]),
                max_cycles: 4_000 * (n as u64 + 2),
            }
        }
        BenchId::BubbleSort => {
            let xs = rng.words(n, -1000, 1000);
            let expect = bubble::reference(&xs);
            Workload {
                bench,
                inject: BTreeMap::from([
                    ("n".into(), vec![xs.len() as Word]),
                    ("x".into(), xs),
                ]),
                expect: BTreeMap::from([("sorted".into(), expect)]),
                max_cycles: 20_000 * (n as u64 * n as u64 + 4),
            }
        }
        BenchId::PopCount => {
            let x = rng.word(0, 32768);
            Workload {
                bench,
                inject: BTreeMap::from([("x".into(), vec![x])]),
                expect: BTreeMap::from([("pc".into(), vec![popcount::reference(x)])]),
                max_cycles: 200_000,
            }
        }
    }
}

/// `count` successive independent workloads — the *waves* the streaming
/// tier admits one after another — deterministically derived from
/// `seed` (wave `i` uses `seed + i`).
pub fn wave_workloads(bench: BenchId, count: usize, n: usize, seed: u64) -> Vec<Workload> {
    (0..count)
        .map(|i| workload(bench, n, seed.wrapping_add(i as u64)))
        .collect()
}

/// Run a workload on the fast engine and check expectations.
pub fn verify(bench: BenchId, n: usize, seed: u64) -> Result<crate::sim::SimOutcome, String> {
    let g = build(bench);
    let wl = workload(bench, n, seed);
    let cfg = wl.sim_config();
    let out = crate::sim::run_token(&g, &cfg);
    for (port, want) in &wl.expect {
        let got = out.stream(port);
        if got != want.as_slice() {
            return Err(format!(
                "{}: port `{port}` mismatch: got {got:?}, want {want:?}",
                bench.slug()
            ));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_benchmarks_verify_small() {
        for b in BenchId::ALL {
            verify(b, 6, 42).unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn all_benchmarks_verify_medium() {
        for b in BenchId::ALL {
            verify(b, 16, 7).unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn workloads_are_deterministic() {
        let a = workload(BenchId::DotProd, 8, 3);
        let b = workload(BenchId::DotProd, 8, 3);
        assert_eq!(a.inject, b.inject);
        assert_eq!(a.expect, b.expect);
        let c = workload(BenchId::DotProd, 8, 4);
        assert_ne!(a.inject, c.inject);
    }

    #[test]
    fn asm_sources_parse_back() {
        for b in BenchId::ALL {
            let text = asm_source(b);
            let g = crate::asm::parse(b.slug(), &text)
                .unwrap_or_else(|e| panic!("{}: {e}", b.slug()));
            assert_eq!(g.n_nodes(), build(b).n_nodes(), "{}", b.slug());
        }
    }

    #[test]
    fn parsed_asm_graphs_still_compute() {
        // The printed assembler is not just pretty text: parse it back and
        // run the workload through the parsed graph.
        for b in [BenchId::Fibonacci, BenchId::DotProd, BenchId::Max] {
            let g = crate::asm::parse(b.slug(), &asm_source(b)).unwrap();
            let wl = workload(b, 8, 11);
            let out = crate::sim::run_token(&g, &wl.sim_config());
            for (port, want) in &wl.expect {
                assert_eq!(out.stream(port), want.as_slice(), "{}", b.slug());
            }
        }
    }

    #[test]
    fn fsm_engine_agrees_on_all_benchmarks() {
        for b in BenchId::ALL {
            let g = build(b);
            let wl = workload(b, 5, 13);
            let mut cfg = wl.sim_config();
            cfg.max_cycles *= 4; // FSM pays handshake cycles
            let fsm = crate::sim::run_fsm(&g, &cfg);
            for (port, want) in &wl.expect {
                assert_eq!(
                    fsm.stream(port),
                    want.as_slice(),
                    "{} on FSM engine",
                    b.slug()
                );
            }
        }
    }

    #[test]
    fn dynamic_engine_agrees_on_all_benchmarks() {
        for b in BenchId::ALL {
            let g = build(b);
            let wl = workload(b, 5, 29);
            let cfg = wl.sim_config();
            let dy = crate::sim::run_dynamic(&g, &cfg, 4);
            for (port, want) in &wl.expect {
                assert_eq!(
                    dy.stream(port),
                    want.as_slice(),
                    "{} on dynamic engine",
                    b.slug()
                );
            }
        }
    }
}
