//! Dot product — Σ x[i]·y[i] over two streams.
//!
//! The multiplier is *not* gated by the loop: it fires as the streams
//! arrive (data-driven), and the count-controlled accumulator loop
//! consumes its products — exactly the producer/consumer elasticity the
//! dataflow model gives for free.

use crate::dfg::{build_loop, Graph, GraphBuilder, Op, Word};

pub const C_SOURCE: &str = "\
in int n;
in stream x;
in stream y;
out int dot;
int acc = 0;
int i = 0;
while (i < n) {
    acc = acc + next(x) * next(y);
    i = i + 1;
}
dot = acc;
";

/// Wrapping dot product.
pub fn reference(xs: &[Word], ys: &[Word]) -> Word {
    xs.iter()
        .zip(ys)
        .fold(0i16, |acc, (&a, &b)| acc.wrapping_add(a.wrapping_mul(b)))
}

/// Ports: `n`, streams `x`/`y` in; `dot` out.
pub fn build() -> Graph {
    let mut b = GraphBuilder::new("dot_prod");
    let n = b.input_port("n");
    let x = b.input_port("x");
    let y = b.input_port("y");
    let i0 = b.constant(0);
    let one0 = b.constant(1);
    let acc0 = b.constant(0);

    // Free-running multiplier over the two streams.
    let prod = b.op2(Op::Mul, x, y);

    // vars: [i, n, one, acc]
    let exits = build_loop(
        &mut b,
        &[i0, n, one0, acc0],
        &[0, 1],
        |b, c| b.op2(Op::IfLt, c[0], c[1]),
        |b, g| {
            let acc_next = b.op2(Op::Add, g[3], prod);
            let (one_use, one_back) = b.copy(g[2]);
            let i_next = b.op2(Op::Add, g[0], one_use);
            vec![i_next, g[1], one_back, acc_next]
        },
    );
    b.rename_arc(exits[3], "dot");
    b.finish().expect("dotprod graph is structurally valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run_token, SimConfig};

    #[test]
    fn computes_dot_product() {
        let g = build();
        let xs = vec![1, 2, 3, 4];
        let ys = vec![10, 20, 30, 40];
        let cfg = SimConfig::new()
            .inject("n", vec![4])
            .inject("x", xs.clone())
            .inject("y", ys.clone());
        let out = run_token(&g, &cfg);
        assert_eq!(out.last("dot"), Some(300));
        assert_eq!(out.last("dot"), Some(reference(&xs, &ys)));
    }

    #[test]
    fn empty_vectors() {
        let g = build();
        let cfg = SimConfig::new().inject("n", vec![0]);
        assert_eq!(run_token(&g, &cfg).last("dot"), Some(0));
    }

    #[test]
    fn wrapping_accumulation() {
        let g = build();
        // 300 * 300 = 90000 wraps in i16.
        let cfg = SimConfig::new()
            .inject("n", vec![1])
            .inject("x", vec![300])
            .inject("y", vec![300]);
        let out = run_token(&g, &cfg);
        assert_eq!(out.last("dot"), Some((300i16).wrapping_mul(300)));
    }

    #[test]
    fn negative_values() {
        let g = build();
        let xs = vec![-3, 5, -7];
        let ys = vec![2, -4, 6];
        let cfg = SimConfig::new()
            .inject("n", vec![3])
            .inject("x", xs.clone())
            .inject("y", ys.clone());
        assert_eq!(run_token(&g, &cfg).last("dot"), Some(reference(&xs, &ys)));
    }
}
