//! Pop count — number of set bits in a word, by shift-and-mask.
//!
//! The loop condition is data-dependent (`w > 0`), not counted — the one
//! benchmark in the suite that exercises the while-schema with a
//! condition computed from a loop-carried value.

use crate::dfg::{build_loop, Graph, GraphBuilder, Op, Word};

pub const C_SOURCE: &str = "\
in int x;
out int pc;
int w = x;
int cnt = 0;
while (w > 0) {
    cnt = cnt + (w & 1);
    w = w >> 1;
}
pc = cnt;
";

/// Bit count (inputs are constrained non-negative: the graph uses an
/// arithmetic shift, as the paper's 16-bit ALU would).
pub fn reference(x: Word) -> Word {
    assert!(x >= 0, "popcount workload is non-negative by contract");
    x.count_ones() as Word
}

/// Ports: `x` in; `pc` out.
pub fn build() -> Graph {
    let mut b = GraphBuilder::new("pop_count");
    let x = b.input_port("x");
    let cnt0 = b.constant(0);
    let zero0 = b.constant(0);
    let one0 = b.constant(1);

    // vars: [w, cnt, zero, one]
    let exits = build_loop(
        &mut b,
        &[x, cnt0, zero0, one0],
        &[0, 2],
        |b, c| b.op2(Op::IfGt, c[0], c[1]),
        |b, g| {
            let (w_mask, w_shift) = b.copy(g[0]);
            let ones = b.copy_n(g[3], 3); // mask, shift amount, recirculate
            let bit = b.op2(Op::And, w_mask, ones[0]);
            let w_next = b.op2(Op::Shr, w_shift, ones[1]);
            let cnt_next = b.op2(Op::Add, g[1], bit);
            vec![w_next, cnt_next, g[2], ones[2]]
        },
    );
    b.rename_arc(exits[1], "pc");
    b.finish().expect("popcount graph is structurally valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run_token, SimConfig};

    #[test]
    fn counts_bits() {
        let g = build();
        for x in [0, 1, 2, 3, 0b1011, 255, 256, 32767] {
            let cfg = SimConfig::new().inject("x", vec![x]).max_cycles(100_000);
            let out = run_token(&g, &cfg);
            assert_eq!(out.last("pc"), Some(reference(x)), "popcount({x})");
        }
    }

    #[test]
    fn zero_has_no_bits() {
        let g = build();
        let cfg = SimConfig::new().inject("x", vec![0]);
        assert_eq!(run_token(&g, &cfg).last("pc"), Some(0));
    }

    #[test]
    fn all_ones_15() {
        let g = build();
        let cfg = SimConfig::new().inject("x", vec![32767]).max_cycles(100_000);
        assert_eq!(run_token(&g, &cfg).last("pc"), Some(15));
    }
}
