//! Bubble sort — n selection passes over a recirculating FIFO.
//!
//! The hardware structure (all in the paper's operator set plus the FIFO
//! substrate node):
//!
//! 1. **Fill phase** — the input stream is copied: one copy flows into
//!    the recirculation FIFO, the other drives a counting loop that
//!    raises a `go` token only after all `n` elements are stored. This
//!    gate is what makes recirculation order-safe: no pass output can
//!    overtake a not-yet-arrived input element.
//! 2. **Pass loop (outer, k = 0..n)** — each pass scans the FIFO once.
//! 3. **Scan loop (inner, j = 0..n)** — a compare-exchange cell: keeps
//!    the running maximum in `carry`, returns the loser to the FIFO. The
//!    pass's carry exit is the k-th largest element → output `sorted`
//!    (descending). The bottom sentinel −32768 seeds each pass's carry
//!    and accumulates harmlessly in the FIFO.
//!
//! Inner-loop re-initialization per outer iteration is the nesting
//! feature of [`build_loop`]; this graph is its stress test.

use crate::dfg::{build_loop, Graph, GraphBuilder, Op, Word};

pub const C_SOURCE: &str = "\
in int n;
in stream x;
out stream sorted;
fifo buf;
int f = 0;
while (f < n) {
    int v = next(x);
    push(buf, v);
    f = f + 1 + (v & 0);   // (v & 0) joins the count to the element:
}                          // the fill counter cannot outrun the stream
int k = 0;
while (k < n) {
    int carry = -32768;
    int j = 0;
    while (j < n) {
        int v = pop(buf);
        if (v > carry) {
            push(buf, carry);
            carry = v;
        } else {
            push(buf, v);
        }
        j = j + 1;
    }
    emit(sorted, carry);
    k = k + 1;
}
";

/// Descending sort (the selection-pass fabric emits largest first).
pub fn reference(xs: &[Word]) -> Vec<Word> {
    let mut v = xs.to_vec();
    v.sort_unstable_by(|a, b| b.cmp(a));
    v
}

/// FIFO capacity: bounds the largest sortable vector. Pinned to the
/// fabric slot provisioning so the physical model's BRAM estimate
/// covers this graph exactly.
pub const FIFO_DEPTH: u16 = crate::dfg::MAX_FIFO_DEPTH;

/// Ports: `n`, stream `x` in; stream `sorted` (descending) and `pf` out.
pub fn build() -> Graph {
    let mut b = GraphBuilder::new("bubble_sort");
    let n_port = b.input_port("n");
    let x = b.input_port("x");

    // The FIFO's output arc is pre-created; the FIFO node itself is wired
    // last, once its input (the recirculation merge) exists.
    let fifo_out = b.wire();

    // ---- Fill phase -------------------------------------------------
    // x is duplicated: one copy into the FIFO, one into the fill counter
    // (the counter "joins" with the data copy so it cannot run ahead).
    let (x_data, x_count) = b.copy(x);

    let f0 = b.constant(0);
    let fill_one0 = b.constant(1);
    let fill_zero0 = b.constant(0);
    // vars: [f, n, one, zero]
    let fill_exits = build_loop(
        &mut b,
        &[f0, n_port, fill_one0, fill_zero0],
        &[0, 1],
        |b, c| b.op2(Op::IfLt, c[0], c[1]),
        |b, g| {
            // t = x_count & 0 — consumes one stream element, value 0.
            let (z_use, z_back) = b.copy(g[3]);
            let t = b.op2(Op::And, x_count, z_use);
            let (one_use, one_back) = b.copy(g[2]);
            let f_inc = b.op2(Op::Add, g[0], one_use);
            let f_next = b.op2(Op::Add, f_inc, t); // join: waits for the element
            vec![f_next, g[1], one_back, z_back]
        },
    );
    // go = final f (== n); k0 = go * 0 — the outer loop cannot start
    // before the fill loop finishes.
    let go = fill_exits[0];
    let k0 = b.op2(Op::Mul, go, fill_exits[3]);

    // ---- Pass + scan loops -------------------------------------------
    let outer_zero0 = b.constant(0);
    let minv0 = b.constant(i16::MIN);
    let mut lo_to_fifo: Option<crate::dfg::ArcId> = None;

    // outer vars: [k, n, one, zero, minv]
    let outer_exits = build_loop(
        &mut b,
        &[k0, fill_exits[1], fill_exits[2], outer_zero0, minv0],
        &[0, 1],
        |b, c| b.op2(Op::IfLt, c[0], c[1]),
        |b, g| {
            let (one_k, one_inner) = b.copy(g[2]);
            let (zero_inner, zero_back) = b.copy(g[3]);
            let (minv_inner, minv_back) = b.copy(g[4]);

            // inner vars: [j, n, one, carry]
            let inner_exits = build_loop(
                b,
                &[zero_inner, g[1], one_inner, minv_inner],
                &[0, 1],
                |b, c| b.op2(Op::IfLt, c[0], c[1]),
                |b, gi| {
                    // v = pop(buf); compare-exchange with carry. The
                    // branch/ndmerge idiom routes winner and loser so
                    // every token is consumed every iteration (a dmerge
                    // select would strand the unselected candidate).
                    let (v_cmp, v_data) = b.copy(fifo_out);
                    let (c_cmp, c_data) = b.copy(gi[3]);
                    let c = b.op2(Op::IfGt, v_cmp, c_cmp); // v > carry
                    let (c_v, c_c) = b.copy(c);
                    let bv = b.node(Op::Branch, &[c_v, v_data], &[]);
                    let (v_win, v_lose) = (b.out_arc(bv, 0), b.out_arc(bv, 1));
                    let bc = b.node(Op::Branch, &[c_c, c_data], &[]);
                    let (carry_lose, carry_win) = (b.out_arc(bc, 0), b.out_arc(bc, 1));
                    let hi_n = b.node(Op::NdMerge, &[v_win, carry_win], &[]);
                    let hi = b.out_arc(hi_n, 0);
                    let lo_n = b.node(Op::NdMerge, &[v_lose, carry_lose], &[]);
                    let lo = b.out_arc(lo_n, 0);
                    lo_to_fifo = Some(lo);
                    let (onei_use, onei_back) = b.copy(gi[2]);
                    let j_next = b.op2(Op::Add, gi[0], onei_use);
                    vec![j_next, gi[1], onei_back, hi]
                },
            );
            // The pass's carry exit is this pass's maximum → `sorted`.
            b.rename_arc(inner_exits[3], "sorted");

            let k_next = b.op2(Op::Add, g[0], one_k);
            vec![
                k_next,
                inner_exits[1],
                inner_exits[2],
                zero_back,
                minv_back,
            ]
        },
    );
    b.rename_arc(outer_exits[0], "pf");

    // ---- Recirculation FIFO ------------------------------------------
    // fifo input = merge(fill stream, pass losers).
    let lo = lo_to_fifo.expect("inner body ran");
    let nm = b.node(Op::NdMerge, &[x_data, lo], &[]);
    let fifo_in = b.out_arc(nm, 0);
    b.node(Op::Fifo(FIFO_DEPTH), &[fifo_in], &[fifo_out]);

    b.finish().expect("bubble-sort graph is structurally valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run_token, SimConfig};

    fn sort_via_fabric(xs: &[Word]) -> Vec<Word> {
        let g = build();
        let cfg = SimConfig::new()
            .inject("n", vec![xs.len() as Word])
            .inject("x", xs.to_vec())
            .max_cycles(20_000 * (xs.len() as u64 * xs.len() as u64 + 4));
        let out = run_token(&g, &cfg);
        out.stream("sorted").to_vec()
    }

    #[test]
    fn sorts_small_vector() {
        assert_eq!(sort_via_fabric(&[3, 1, 2]), vec![3, 2, 1]);
    }

    #[test]
    fn sorts_with_duplicates_and_negatives() {
        let xs = [5, -2, 5, 0, -2, 9];
        assert_eq!(sort_via_fabric(&xs), reference(&xs));
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(sort_via_fabric(&[]), Vec::<Word>::new());
        assert_eq!(sort_via_fabric(&[42]), vec![42]);
    }

    #[test]
    fn already_sorted_and_reversed() {
        let asc: Vec<Word> = (1..=8).collect();
        let desc: Vec<Word> = (1..=8).rev().collect();
        assert_eq!(sort_via_fabric(&asc), desc);
        assert_eq!(sort_via_fabric(&desc), desc);
    }
}
