//! Bench: the four Fig. 8 panels (FF / LUT / Slices / Fmax bar series)
//! plus the latency-cycles comparison that backs the paper's
//! "acceleration" claim — execution cycles of the dataflow fabric
//! (measured on the cycle-accurate FSM engine) against the sequential
//! C-to-Verilog schedule and the LALP pipeline models, across workload
//! sizes. Absolute winners follow each system's Fmax × cycles.

use dataflow_accel::baselines::{ctv, kernel_spec, lalp};
use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::estimate::estimate;
use dataflow_accel::report;
use dataflow_accel::sim::run_fsm;

fn main() {
    println!("=== Fig. 8 panels (CSV) ===");
    print!("{}", report::fig8_csv());

    println!();
    println!("=== latency series: cycles (and µs at each system's Fmax) ===");
    println!("benchmark,n,ours_cycles,ctv_cycles,lalp_cycles,ours_us,ctv_us,lalp_us");
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let ours_fmax = estimate(&g).fmax_mhz;
        let spec = kernel_spec(b);
        let c_est = ctv::estimate(&spec);
        let l_est = lalp::estimate(&spec);
        for n in [4usize, 8, 16, 32] {
            let wl = bench_defs::workload(b, n, 11);
            let mut cfg = wl.sim_config();
            cfg.max_cycles *= 8;
            let out = run_fsm(&g, &cfg);
            let ctv_cycles = ctv::latency_cycles(&spec, n as u64);
            let lalp_cycles = lalp::latency_cycles(&spec, n as u64);
            let ours_us = out.cycles as f64 / ours_fmax;
            let ctv_us = ctv_cycles as f64 / c_est.fmax_mhz;
            let lalp_us = l_est
                .map(|l| lalp_cycles as f64 / l.fmax_mhz)
                .unwrap_or(f64::NAN);
            println!(
                "{},{},{},{},{},{:.3},{:.3},{:.3}",
                b.slug(),
                n,
                out.cycles,
                ctv_cycles,
                lalp_cycles,
                ours_us,
                ctv_us,
                lalp_us
            );
        }
    }
}
