//! Bench: the L3 hot path — operator-firing throughput of the token
//! engine and clock-edge throughput of the cycle-accurate FSM engine.
//! §Perf targets in DESIGN.md are measured here.

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::sim::{run_fsm, run_token, FsmSim, TokenSim};
use dataflow_accel::util::bench::{fmt_ns, report, run, BenchCfg};

fn main() {
    println!("=== simulation hot path ===");
    let cfg = BenchCfg {
        warmup_iters: 3,
        samples: 20,
        iters_per_sample: 1,
    };

    // Token engine: firings/sec on each benchmark at a fixed size.
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let n = if b == BenchId::BubbleSort { 16 } else { 64 };
        let wl = bench_defs::workload(b, n, 5);
        let scfg = wl.sim_config();
        let mut firings = 0u64;
        let m = run(&format!("token/{}/n{}", b.slug(), n), cfg, || {
            let out = run_token(&g, &scfg);
            firings = out.firings;
            out.cycles
        });
        println!(
            "    → {:.1} M firings/s ({} firings/run)",
            firings as f64 / (m.median_ns * 1e-9) / 1e6,
            firings
        );
        report(&m);
    }

    // FSM engine: clock edges/sec — every operator FSM + every handshake
    // wire evaluated per edge, the software analogue of the fabric clock.
    for b in [BenchId::Fibonacci, BenchId::DotProd] {
        let g = bench_defs::build(b);
        let wl = bench_defs::workload(b, 32, 5);
        let mut scfg = wl.sim_config();
        scfg.max_cycles *= 8;
        let mut cycles = 0u64;
        let m = run(&format!("fsm/{}/n32", b.slug()), cfg, || {
            let out = run_fsm(&g, &scfg);
            cycles = out.cycles;
            cycles
        });
        let edges_per_sec = cycles as f64 / (m.median_ns * 1e-9);
        let node_evals = edges_per_sec * g.n_nodes() as f64;
        println!(
            "    → {:.2} M clock edges/s × {} operators = {:.1} M operator-FSM evals/s",
            edges_per_sec / 1e6,
            g.n_nodes(),
            node_evals / 1e6
        );
        report(&m);
    }

    // Raw step cost: one token-engine round on the biggest graph.
    let g = bench_defs::build(BenchId::BubbleSort);
    let wl = bench_defs::workload(BenchId::BubbleSort, 24, 3);
    let scfg = wl.sim_config();
    let m = run(
        "token/bubble_sort/single_round",
        BenchCfg {
            warmup_iters: 1,
            samples: 30,
            iters_per_sample: 1,
        },
        || {
            let mut sim = TokenSim::new(&g, &scfg);
            for _ in 0..1000 {
                sim.step();
            }
        },
    );
    println!(
        "    → {} per round ({} nodes)",
        fmt_ns(m.median_ns / 1000.0),
        g.n_nodes()
    );
    report(&m);

    let m = run(
        "fsm/bubble_sort/single_edge",
        BenchCfg {
            warmup_iters: 1,
            samples: 30,
            iters_per_sample: 1,
        },
        || {
            let mut sim = FsmSim::new(&g, &scfg);
            for _ in 0..1000 {
                sim.step();
            }
        },
    );
    println!("    → {} per clock edge", fmt_ns(m.median_ns / 1000.0));
    report(&m);
}
