//! Bench: the XLA fabric-offload path — raw PJRT step latency per
//! artifact shape, and batched-sweep throughput of the XLA engine vs the
//! native ALU engine. §Perf's offload numbers come from here.

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::coordinator::{run_batch_native, run_batch_xla};
use dataflow_accel::runtime::{FabricBatch, FabricRuntime};
use dataflow_accel::util::bench::{report, run, BenchCfg};
use dataflow_accel::util::Rng;

fn main() {
    println!("=== fabric offload ===");
    let Ok(rt) = FabricRuntime::load("artifacts") else {
        println!("artifacts not built (run `make artifacts`); skipping");
        return;
    };
    let cfg = BenchCfg {
        warmup_iters: 5,
        samples: 25,
        iters_per_sample: 4,
    };

    // Raw PJRT dispatch+execute latency per artifact shape.
    for (b, n) in rt.shapes() {
        let mut rng = Rng::new(1);
        let mut fb = FabricBatch::zeroed(b, n);
        for i in 0..n {
            fb.opcode[i] = (i % 15) as i32;
        }
        for s in 0..b * n {
            fb.a[s] = rng.word(-1000, 1000) as i32;
            fb.b[s] = rng.word(-1000, 1000) as i32;
            fb.fire[s] = 1;
        }
        let m = run(&format!("pjrt_step/{b}x{n}"), cfg, || {
            rt.step(&fb).unwrap().len()
        });
        let slots = (b * n) as f64;
        println!(
            "    → {:.1} M ALU slots/s",
            slots / (m.median_ns * 1e-9) / 1e6
        );
        report(&m);
    }

    // Batched benchmark sweep: native vs XLA engine, same workloads.
    for bench in [BenchId::Fibonacci, BenchId::DotProd, BenchId::VectorSum] {
        let g = bench_defs::build(bench);
        for batch in [8usize, 64] {
            let cfgs: Vec<_> = (0..batch)
                .map(|s| bench_defs::workload(bench, 12, s as u64).sim_config())
                .collect();
            let mn = run(
                &format!("batch_native/{}/b{}", bench.slug(), batch),
                BenchCfg {
                    warmup_iters: 2,
                    samples: 10,
                    iters_per_sample: 1,
                },
                || run_batch_native(&g, &cfgs).len(),
            );
            report(&mn);
            let mx = run(
                &format!("batch_xla/{}/b{}", bench.slug(), batch),
                BenchCfg {
                    warmup_iters: 2,
                    samples: 10,
                    iters_per_sample: 1,
                },
                || run_batch_xla(&g, &cfgs, &rt).unwrap().len(),
            );
            report(&mx);
            println!(
                "    → xla/native ratio {:.2}× (instances {}, graph {} nodes)",
                mx.median_ns / mn.median_ns,
                batch,
                g.n_nodes()
            );
        }
    }
}
