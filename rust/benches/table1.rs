//! Bench: regenerate every Table-1 row and time the full pipeline
//! (C compile → graph → resource estimate) per benchmark — the paper's
//! entire Table 1, one harness.

use dataflow_accel::baselines::{ctv, kernel_spec, lalp};
use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::estimate::{estimate, estimate_trimmed};
use dataflow_accel::frontend;
use dataflow_accel::report;
use dataflow_accel::util::bench::{report as breport, run, BenchCfg};

fn main() {
    println!("=== Table 1 regeneration bench ===");
    let cfg = BenchCfg {
        warmup_iters: 2,
        samples: 15,
        iters_per_sample: 1,
    };

    for b in BenchId::ALL {
        let m = run(&format!("table1/{}/pipeline", b.slug()), cfg, || {
            let g = frontend::compile(b.slug(), bench_defs::c_source(b)).unwrap();
            let ours = estimate(&g);
            let trimmed = estimate_trimmed(&g);
            let c = ctv::estimate(&kernel_spec(b));
            let l = lalp::estimate(&kernel_spec(b));
            (ours.ff, trimmed.ff, c.ff, l.map(|r| r.ff).unwrap_or(0))
        });
        breport(&m);
    }

    let m = run("table1/full_table_render", cfg, report::table1);
    breport(&m);

    println!();
    print!("{}", report::table1());
}
