//! Ablation: the static single-token rule vs the dynamic (tagged-token)
//! extension the paper leaves as future work.
//!
//! For each benchmark and queue bound k ∈ {1, 2, 4, 8}, measure rounds
//! to completion. k = 1 is exactly the paper's static model; larger k
//! recovers pipeline parallelism on stream-shaped graphs (vector sum,
//! dot product) and shows little effect on strictly loop-carried graphs
//! (fibonacci, popcount) — quantifying the paper's own conjecture that
//! a dynamic model would "obtain a better performance".

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::sim::{run_dynamic, run_token};
use dataflow_accel::util::bench::{report, run, BenchCfg};

fn main() {
    println!("=== static vs dynamic ablation ===");
    println!("benchmark,n,bound,rounds,speedup_vs_static");
    let tcfg = BenchCfg {
        warmup_iters: 1,
        samples: 8,
        iters_per_sample: 1,
    };
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let n = if b == BenchId::BubbleSort { 12 } else { 64 };
        let wl = bench_defs::workload(b, n, 9);
        let cfg = wl.sim_config();

        let static_out = run_token(&g, &cfg);
        for bound in [1usize, 2, 4, 8] {
            let out = run_dynamic(&g, &cfg, bound);
            // Results must be identical; only timing may change.
            assert_eq!(
                out.outputs, static_out.outputs,
                "{} bound {bound} diverged",
                b.slug()
            );
            println!(
                "{},{},{},{},{:.2}",
                b.slug(),
                n,
                bound,
                out.cycles,
                static_out.cycles as f64 / out.cycles as f64
            );
        }

        let m = run(&format!("dynamic_k4/{}/n{}", b.slug(), n), tcfg, || {
            run_dynamic(&g, &cfg, 4).cycles
        });
        report(&m);
    }
}
