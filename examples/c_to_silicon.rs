//! C → dataflow graph → VHDL: the complete compilation chain the paper
//! names as its goal ("convert parts of programs written in C language
//! into a static dataflow model implemented in a FPGA") plus its future
//! work ("a module to convert C directly into a VHDL").
//!
//! Reads a mini-C file (or a built-in demo), compiles it, simulates it
//! on a workload, prints the resource estimate and writes the VHDL.
//!
//! ```sh
//! cargo run --release --example c_to_silicon -- [file.c] [--out design.vhdl]
//! ```

use dataflow_accel::sim::{run_token, SimConfig};
use dataflow_accel::util::args::Args;
use dataflow_accel::{asm, estimate, frontend, vhdl};

const DEMO: &str = "\
// demo: sum of squares of a stream, gated by a count
in int n;
in stream x;
out int sumsq;
int acc = 0;
int i = 0;
while (i < n) {
    int v = next(x);
    acc = acc + v * v;
    i = i + 1;
}
sumsq = acc;
";

fn main() {
    let args = Args::parse(std::env::args().skip(1), &[]);
    let (name, src) = match args.positional.first() {
        Some(path) => (
            path.rsplit('/').next().unwrap().trim_end_matches(".c").to_string(),
            std::fs::read_to_string(path).expect("read source file"),
        ),
        None => ("sum_of_squares".to_string(), DEMO.to_string()),
    };

    println!("--- source ---\n{src}");
    let g = frontend::compile(&name, &src).expect("compiles");
    println!(
        "graph: {} operators, {} channels; census: {:?}",
        g.n_nodes(),
        g.n_arcs(),
        g.op_census()
    );

    // Simulate on a demo workload when the ports match the demo's.
    if g.arc_by_name("n").is_some() && g.arc_by_name("x").is_some() {
        let xs: Vec<i16> = vec![1, 2, 3, 4, 5];
        let cfg = SimConfig::new()
            .inject("n", vec![xs.len() as i16])
            .inject("x", xs.clone())
            .max_cycles(1_000_000);
        let out = run_token(&g, &cfg);
        println!("simulation outputs: {:?}", out.outputs);
    }

    // Resource estimate (the paper's Table-1 quantities).
    let r = estimate::estimate(&g);
    println!(
        "resources: FF {} LUT {} slices {} bram {} bits | fmax {:.1} MHz",
        r.ff, r.lut, r.slices, r.bram_bits, r.fmax_mhz
    );

    // Assembler + VHDL artifacts.
    println!("--- assembler ---\n{}", asm::print(&g));
    let design = vhdl::generate(&g);
    let out_path = args.get_or("out", &format!("/tmp/{name}.vhdl"));
    std::fs::write(&out_path, design.render()).expect("write VHDL");
    println!(
        "VHDL: {} entities + top netlist → {out_path}",
        design.entities.len()
    );
}
