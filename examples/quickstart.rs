//! Quickstart: the paper's worked example end to end.
//!
//! Builds the Fibonacci dataflow graph three ways — from the assembler
//! language (Listing 1 style), from mini-C through the frontend, and
//! from the programmatic builder — and runs it on all three simulation
//! engines, checking they agree.
//!
//! ```sh
//! cargo run --release --example quickstart -- --n 12
//! ```

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::sim::{run_dynamic, run_fsm, run_token, SimConfig};
use dataflow_accel::util::args::Args;
use dataflow_accel::{asm, frontend};

fn main() {
    let args = Args::parse(std::env::args().skip(1), &[]);
    let n = args.get_usize("n", 12) as i16;

    // 1. The hand-built graph (the paper's Fig. 7 in builder form).
    let g_built = bench_defs::build(BenchId::Fibonacci);
    println!(
        "built graph: {} operators, {} channels",
        g_built.n_nodes(),
        g_built.n_arcs()
    );

    // 2. Print it as dataflow assembler (the paper's Listing 1 format),
    //    then parse that text back — the artifact round trip.
    let listing = asm::print(&g_built);
    println!("--- assembler (first 6 statements) ---");
    for line in listing.lines().take(6) {
        println!("{line}");
    }
    println!("    … ({} statements total)", listing.lines().count());
    let g_asm = asm::parse("fibonacci", &listing).expect("assembler parses");

    // 3. Compile the same algorithm from mini-C (the paper's future work).
    let g_c = frontend::compile("fibonacci", bench_defs::c_source(BenchId::Fibonacci))
        .expect("C source compiles");
    println!(
        "C-compiled graph: {} operators (schema-lowered)",
        g_c.n_nodes()
    );

    // Run all of them on all engines.
    let cfg = SimConfig::new().inject("n", vec![n]).max_cycles(1_000_000);
    let expect = bench_defs::fib::reference(n);
    for (name, g) in [("built", &g_built), ("asm", &g_asm), ("c", &g_c)] {
        let tok = run_token(g, &cfg);
        let fsm = run_fsm(g, &cfg);
        let dyn4 = run_dynamic(g, &cfg, 4);
        assert_eq!(tok.last("fibo"), Some(expect), "{name} token engine");
        assert_eq!(fsm.last("fibo"), Some(expect), "{name} fsm engine");
        assert_eq!(dyn4.last("fibo"), Some(expect), "{name} dynamic engine");
        println!(
            "{name:>6}: fib({n}) = {expect} | token {} rounds, fsm {} clock cycles, dynamic {} rounds",
            tok.cycles, fsm.cycles, dyn4.cycles
        );
    }
    println!("all engines agree ✓");
}
