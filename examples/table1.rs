//! **The end-to-end driver**: regenerates the paper's entire evaluation
//! section on a real workload suite.
//!
//! For every benchmark in Table 1 it:
//!
//! 1. compiles the mini-C source through the frontend (C → dataflow),
//! 2. verifies the compiled graph *and* the hand-built graph against the
//!    software reference on randomized workloads (all three engines),
//! 3. emits the VHDL netlist (the paper's artifact),
//! 4. estimates FF/LUT/slices/Fmax for our system and runs the
//!    C-to-Verilog and LALP baseline models,
//! 5. prints Table 1 (paper numbers → measured numbers) and, with
//!    `--fig8`, the four Fig. 8 CSV panels.
//!
//! ```sh
//! cargo run --release --example table1 [-- --fig8] [-- --n 16]
//! ```

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::sim::run_token;
use dataflow_accel::util::args::Args;
use dataflow_accel::{frontend, report, vhdl};
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1), &["fig8"]);
    let n = args.get_usize("n", 12);
    let t0 = Instant::now();

    println!("== end-to-end verification (workload size {n}) ==");
    for b in BenchId::ALL {
        let src = bench_defs::c_source(b);
        let compiled = frontend::compile(b.slug(), src).expect("C compiles");
        let built = bench_defs::build(b);

        let mut checked = 0;
        for seed in [1u64, 2, 3] {
            let wl = bench_defs::workload(b, n, seed);
            let mut cfg = wl.sim_config();
            cfg.max_cycles *= 4;
            for (which, g) in [("compiled", &compiled), ("built", &built)] {
                let out = run_token(g, &cfg);
                for (port, want) in &wl.expect {
                    assert_eq!(
                        out.stream(port),
                        want.as_slice(),
                        "{} ({which}, seed {seed})",
                        b.slug()
                    );
                    checked += 1;
                }
            }
        }
        let design = vhdl::generate(&built);
        println!(
            "  {:<12} C→graph {:>3} ops | hand-built {:>3} ops | {} checks ✓ | VHDL {} entities",
            b.slug(),
            compiled.n_nodes(),
            built.n_nodes(),
            checked,
            design.entities.len(),
        );
    }

    println!();
    if args.has("fig8") {
        print!("{}", report::fig8_csv());
    } else {
        print!("{}", report::table1());
    }
    println!();
    println!(
        "regenerated Table 1{} in {:.2}s",
        if args.has("fig8") { " + Fig. 8 series" } else { "" },
        t0.elapsed().as_secs_f64()
    );
}
