//! Accelerated parameter sweep through the coordinator: many benchmark
//! instances batched through the AOT-compiled XLA fabric kernel, with
//! the native-ALU path as the baseline — the three-layer system working
//! end to end (Rust router/batcher → PJRT → Pallas-lowered HLO).
//!
//! ```sh
//! cargo run --release --example accel_sweep -- \
//!     [--requests 48] [--n 12] [--workers 2] [--batch 8]
//! ```

use dataflow_accel::bench_defs::BenchId;
use dataflow_accel::coordinator::{Coordinator, Engine, Request};
use dataflow_accel::util::args::Args;
use std::time::Instant;

fn sweep(engine: Engine, requests: usize, n: usize, workers: usize, batch: usize) -> (f64, u64) {
    let c = Coordinator::start(workers, engine, Some("artifacts"), batch)
        .expect("coordinator start");
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| {
            c.submit(Request {
                bench: BenchId::ALL[i % BenchId::ALL.len()],
                n,
                seed: i as u64,
            })
        })
        .collect();
    let mut verified = 0u64;
    for rx in rxs {
        let resp = rx.recv().expect("response");
        assert!(
            resp.verified,
            "{:?} failed verification on {:?} engine",
            resp.request, engine
        );
        verified += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "  {:?}: {}",
        engine,
        c.metrics.summary()
    );
    c.shutdown();
    (requests as f64 / dt, verified)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), &[]);
    let requests = args.get_usize("requests", 48);
    let n = args.get_usize("n", 12);
    let workers = args.get_usize("workers", 2);
    let batch = args.get_usize("batch", 8);

    println!("== sweep: {requests} requests over all 6 benchmarks, n={n} ==");
    let (native_rps, v1) = sweep(Engine::Native, requests, n, workers, batch);
    let (xla_rps, v2) = sweep(Engine::Xla, requests, n, workers, batch);
    assert_eq!(v1, requests as u64);
    assert_eq!(v2, requests as u64);
    println!();
    println!("  native ALU : {native_rps:>8.1} req/s");
    println!("  XLA fabric : {xla_rps:>8.1} req/s");
    println!(
        "  note: on CPU-PJRT the XLA path pays per-tick dispatch; its win \
         condition is large batches of wide graphs (see EXPERIMENTS.md §offload)."
    );
}
